//! Directional, purpose-tagged message ledger (DESIGN.md §9).
//!
//! The paper's whole subject is the communication/performance trade-off,
//! so the communication accounting has to be exact. The ledger replaces
//! the original frame-level meter (which billed transmitters only) with
//! a model of every metered exchange as a directed, purpose-tagged
//! message:
//!
//! ```text
//!   (source, destination, purpose, payload scalars × payload width)
//!
//!   purpose ∈ { estimate-broadcast,   unsolicited push of (masked)
//!                                     estimate entries,
//!               gradient-reply,       reply to a soliciting estimate
//!                                     broadcast,
//!               dcd-residue }         compressive diffusion's one-scalar
//!                                     projection residue
//! ```
//!
//! Billing rules (the §9 message grammar):
//!
//! 1. A **gated (silent) transmitter** puts nothing on the air: none of
//!    its messages are billed (unchanged from the mute-mask meter).
//! 2. A **broadcast** (estimate or residue) from an on-air transmitter
//!    is always billed — the energy is spent whether or not a lossy
//!    link erases the frame in flight (receiver-side erasure,
//!    cf. arXiv:1408.5845).
//! 3. A **solicited reply** (gradient) is billed only when its request
//!    leg was actually delivered: a reply to a gated or erased estimate
//!    broadcast was never computed, never transmitted, never billed.
//!    The scalars rule 3 saves relative to the old transmitter-only
//!    meter are tracked in [`CommLedger::suppressed_scalars`], so
//!    `scalars + suppressed_scalars` reproduces the legacy bill.
//!
//! Payload width: a full-precision scalar is 64 bits on the wire; under
//! the quantization impairment a scalar is a fixed-point index into the
//! Δ grid of the `[-PAYLOAD_RANGE, PAYLOAD_RANGE]` dynamic range,
//! [`payload_bits`] wide. Billed bits are `scalars × width`.
//!
//! Determinism: the ledger draws no randomness and all counters are
//! integers, so billed scalars/bits are associative under merging —
//! bit-identical for any worker-thread or shard layout. On ideal links
//! no outcome table is installed and every send is billed, which is
//! exactly the legacy accounting (the bit-identity argument of §9).

use std::collections::BTreeMap;

use crate::topology::Graph;

/// Node count above which the per-link scalar table switches from a
/// dense `N²` array to a sorted sparse map. Every historical preset
/// (≤ 80 nodes) stays on the dense path, so its counters, merge order
/// and serialized form are untouched; the large-N `mega-grid` scenarios
/// (N ≥ 10⁵, where a dense table would be 10¹⁰ entries) get O(edges
/// actually billed) storage instead.
pub const DENSE_LINK_LIMIT: usize = 1024;

/// Billed scalars per directed link, keyed by the dense index
/// `src * n + dst`. Dense below [`DENSE_LINK_LIMIT`] nodes, sparse
/// (sorted map) above it; the two variants are logically identical —
/// iteration and equality only ever observe nonzero entries in
/// ascending index order.
#[derive(Debug, Clone)]
pub enum LinkCounts {
    Dense { n: usize, counts: Vec<u64> },
    Sparse { n: usize, counts: BTreeMap<u64, u64> },
}

impl LinkCounts {
    /// An all-zero table for an `n`-node network.
    pub fn for_nodes(n: usize) -> Self {
        if n <= DENSE_LINK_LIMIT {
            LinkCounts::Dense { n, counts: vec![0; n * n] }
        } else {
            LinkCounts::Sparse { n, counts: BTreeMap::new() }
        }
    }

    /// Number of nodes the table was sized for.
    pub fn n_nodes(&self) -> usize {
        match self {
            LinkCounts::Dense { n, .. } | LinkCounts::Sparse { n, .. } => *n,
        }
    }

    /// Count at dense index `idx` (= `src * n + dst`).
    pub fn get(&self, idx: usize) -> u64 {
        match self {
            LinkCounts::Dense { counts, .. } => counts[idx],
            LinkCounts::Sparse { counts, .. } => counts.get(&(idx as u64)).copied().unwrap_or(0),
        }
    }

    /// Add `count` scalars at dense index `idx`.
    #[inline]
    pub fn add(&mut self, idx: usize, count: u64) {
        match self {
            LinkCounts::Dense { counts, .. } => counts[idx] += count,
            LinkCounts::Sparse { counts, .. } => *counts.entry(idx as u64).or_insert(0) += count,
        }
    }

    /// Overwrite the count at dense index `idx` (deserialization).
    pub fn set(&mut self, idx: usize, count: u64) {
        match self {
            LinkCounts::Dense { counts, .. } => counts[idx] = count,
            LinkCounts::Sparse { counts, .. } => {
                if count == 0 {
                    counts.remove(&(idx as u64));
                } else {
                    counts.insert(idx as u64, count);
                }
            }
        }
    }

    /// Stored counts (zeros included on the dense path) — supports the
    /// historical `.iter().sum::<u64>()` total.
    pub fn iter(&self) -> Box<dyn Iterator<Item = u64> + '_> {
        match self {
            LinkCounts::Dense { counts, .. } => Box::new(counts.iter().copied()),
            LinkCounts::Sparse { counts, .. } => Box::new(counts.values().copied()),
        }
    }

    /// Nonzero `(dense index, count)` pairs in ascending index order —
    /// the canonical form used for serialization, CSV emission, merging
    /// and equality (identical for both variants).
    pub fn pairs(&self) -> Box<dyn Iterator<Item = (usize, u64)> + '_> {
        match self {
            LinkCounts::Dense { counts, .. } => Box::new(
                counts
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c > 0)
                    .map(|(i, &c)| (i, c)),
            ),
            LinkCounts::Sparse { counts, .. } => {
                Box::new(counts.iter().map(|(&i, &c)| (i as usize, c)))
            }
        }
    }

    /// Accumulate another table (integer adds — order-independent).
    pub fn merge(&mut self, other: &LinkCounts) {
        for (idx, count) in other.pairs() {
            self.add(idx, count);
        }
    }
}

impl PartialEq for LinkCounts {
    /// Logical equality: same network size, same nonzero entries —
    /// a dense and a sparse table with equal content compare equal.
    fn eq(&self, other: &Self) -> bool {
        self.n_nodes() == other.n_nodes() && self.pairs().eq(other.pairs())
    }
}

/// Per-iteration request-delivery outcomes, stored edge-indexed
/// (receiver-major CSR over the graph, mirroring
/// [`Combiner`](crate::topology::Combiner)): row `dst` lists the sender
/// ids whose broadcasts can reach `dst`, each with a delivered flag.
/// O(E) instead of the dense `N²` bool table, which is what lets the
/// impairment layer run at N = 10⁵. Pairs that are not stored count as
/// delivered (matching the dense table's `true` default).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkOutcomes {
    n: usize,
    /// Receiver `dst`'s senders span `indptr[dst]..indptr[dst + 1]`.
    indptr: Vec<usize>,
    /// Sender ids per receiver row, sorted ascending.
    src: Vec<usize>,
    ok: Vec<bool>,
}

impl LinkOutcomes {
    /// All-delivered outcomes over a graph's directed edges.
    pub fn for_graph(g: &Graph) -> Self {
        let n = g.n();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut src = Vec::new();
        indptr.push(0);
        for k in 0..n {
            src.extend_from_slice(g.neighbors(k));
            indptr.push(src.len());
        }
        let ok = vec![true; src.len()];
        Self { n, indptr, src, ok }
    }

    /// All-delivered outcomes over every (src, dst) pair — test helper
    /// standing in for the historical dense table.
    pub fn fully_connected(n: usize) -> Self {
        let mut indptr = Vec::with_capacity(n + 1);
        let mut src = Vec::with_capacity(n * n);
        indptr.push(0);
        for _ in 0..n {
            src.extend(0..n);
            indptr.push(src.len());
        }
        let ok = vec![true; src.len()];
        Self { n, indptr, src, ok }
    }

    /// Whether no outcome table is installed (every send delivered).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of stored directed links.
    pub fn n_links(&self) -> usize {
        self.src.len()
    }

    /// Did `src`'s broadcast reach `dst`? Unstored pairs are delivered.
    #[inline]
    pub fn delivered(&self, src: usize, dst: usize) -> bool {
        let span = self.indptr[dst]..self.indptr[dst + 1];
        match self.src[span.clone()].binary_search(&src) {
            Ok(i) => self.ok[span.start + i],
            Err(_) => true,
        }
    }

    /// Set the outcome of the stored link `src → dst` (binary search;
    /// panics if the pair is not stored).
    pub fn set(&mut self, src: usize, dst: usize, delivered: bool) {
        let span = self.indptr[dst]..self.indptr[dst + 1];
        let i = self.src[span.clone()]
            .binary_search(&src)
            .unwrap_or_else(|_| panic!("link {src} -> {dst} not stored"));
        self.ok[span.start + i] = delivered;
    }

    /// Set the outcome of receiver `dst`'s `slot`-th stored in-link
    /// (slots follow the graph's sorted neighbour order) — the O(1)
    /// write the per-edge impairment rebuild uses.
    #[inline]
    pub fn set_row_slot(&mut self, dst: usize, slot: usize, delivered: bool) {
        self.ok[self.indptr[dst] + slot] = delivered;
    }

    /// Mark every stored link delivered.
    pub fn reset_all_true(&mut self) {
        self.ok.iter_mut().for_each(|x| *x = true);
    }

    /// Replace contents with `other`, reusing existing buffers.
    pub fn copy_from(&mut self, other: &LinkOutcomes) {
        self.n = other.n;
        self.indptr.clone_from(&other.indptr);
        self.src.clone_from(&other.src);
        self.ok.clone_from(&other.ok);
    }

    /// Remove the table (back to the every-send-delivered default).
    pub fn clear(&mut self) {
        self.n = 0;
        self.indptr.clear();
        self.src.clear();
        self.ok.clear();
    }
}

/// What a metered message is *for* — the purpose axis of the ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    /// Unsolicited (masked) estimate entries: DCD/CD `H_k ∘ w_k`
    /// broadcasts, partial-diffusion `H_k ∘ ψ_k`, RCD's polled ψ, and
    /// diffusion LMS's full-estimate exchanges.
    Estimate,
    /// A solicited gradient reply `Q_l ∘ ∇J_l` (DCD/CD/diffusion LMS):
    /// only transmitted when the soliciting estimate broadcast arrived.
    Gradient,
    /// Compressive diffusion's one-scalar projection residue.
    Residue,
}

/// Number of [`Purpose`] variants (sizes the per-purpose counters).
pub const N_PURPOSES: usize = 3;

impl Purpose {
    /// All purposes, in counter order.
    pub const ALL: [Purpose; N_PURPOSES] = [Purpose::Estimate, Purpose::Gradient, Purpose::Residue];

    /// Counter index of this purpose.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Purpose::Estimate => 0,
            Purpose::Gradient => 1,
            Purpose::Residue => 2,
        }
    }

    /// Stable label used in result columns and JSON manifests.
    pub fn label(self) -> &'static str {
        match self {
            Purpose::Estimate => "estimate-broadcast",
            Purpose::Gradient => "gradient-reply",
            Purpose::Residue => "dcd-residue",
        }
    }
}

/// Wire width of one full-precision scalar (bits).
pub const FULL_PRECISION_BITS: u32 = 64;

/// Half-width R of the fixed-point dynamic range `[-R, R]` quantized
/// payloads are billed over. The paper's data model draws each entry of
/// w° from a standard Gaussian, so ±8 covers every estimate a
/// converging network transmits to ≈8σ (per-entry excursion
/// probability ~1e-15); the simulated quantizer itself is unbounded —
/// this is a fixed-point wire format, not an entropy bound.
pub const PAYLOAD_RANGE: f64 = 8.0;

/// Wire width of one scalar under the quantization impairment: a
/// mid-tread quantizer of step Δ over the dynamic range
/// `[-PAYLOAD_RANGE, PAYLOAD_RANGE]` has `2R/Δ + 1` levels, so a grid
/// index costs `⌈log₂ levels⌉` bits (clamped to `[2, 64]`). `Δ <= 0`
/// means full precision (DESIGN.md §9).
pub fn payload_bits(quant_step: f64) -> u32 {
    if quant_step <= 0.0 || !quant_step.is_finite() {
        return FULL_PRECISION_BITS;
    }
    let levels = (2.0 * PAYLOAD_RANGE / quant_step + 1.0).max(2.0);
    (levels.log2().ceil() as u32).clamp(2, FULL_PRECISION_BITS)
}

/// The billed totals of one run (or the merged totals of many runs):
/// pure integer counters, so merging is associative and sharded /
/// threaded runs reproduce the serial bill bit for bit (DESIGN.md §9).
#[derive(Debug, Clone, PartialEq)]
pub struct CommLedger {
    /// Number of nodes (sizes the per-node / per-link tables).
    pub n_nodes: usize,
    /// Total billed scalars.
    pub scalars: u64,
    /// Total billed messages (one per directed metered send).
    pub messages: u64,
    /// Scalars the legacy transmitter-only meter would have billed on
    /// top of `scalars`: solicited replies whose request leg was gated
    /// or erased (billing rule 3).
    pub suppressed_scalars: u64,
    /// Billed scalars that were erased in flight (transmitter paid,
    /// receiver got nothing — the bus face's drop accounting).
    pub dropped_scalars: u64,
    /// Billed messages erased in flight.
    pub dropped_messages: u64,
    /// Wire width of one scalar (64 = full precision; see
    /// [`payload_bits`]).
    pub bits_per_scalar: u32,
    /// Billed scalars per transmitting node (length `n_nodes`).
    pub per_node: Vec<u64>,
    /// Billed scalars per purpose ([`Purpose::index`] order).
    pub per_purpose: [u64; N_PURPOSES],
    /// Billed scalars per directed link, keyed `src * n_nodes + dst`
    /// (dense below [`DENSE_LINK_LIMIT`] nodes, sparse above).
    pub per_link: LinkCounts,
}

impl CommLedger {
    /// An all-zero ledger for `n_nodes` nodes at full precision.
    pub fn empty(n_nodes: usize) -> Self {
        Self {
            n_nodes,
            scalars: 0,
            messages: 0,
            suppressed_scalars: 0,
            dropped_scalars: 0,
            dropped_messages: 0,
            bits_per_scalar: FULL_PRECISION_BITS,
            per_node: vec![0; n_nodes],
            per_purpose: [0; N_PURPOSES],
            per_link: LinkCounts::for_nodes(n_nodes),
        }
    }

    /// Total billed payload bits.
    pub fn bits(&self) -> u64 {
        self.scalars * self.bits_per_scalar as u64
    }

    /// Billed payload bits transmitted by node `k`.
    pub fn per_node_bits(&self, k: usize) -> u64 {
        self.per_node[k] * self.bits_per_scalar as u64
    }

    /// Billed scalars on the directed link `src → dst`.
    pub fn link_scalars(&self, src: usize, dst: usize) -> u64 {
        self.per_link.get(src * self.n_nodes + dst)
    }

    /// Billed scalars for one purpose.
    pub fn purpose_scalars(&self, p: Purpose) -> u64 {
        self.per_purpose[p.index()]
    }

    /// What the legacy transmitter-only meter would have billed: the
    /// exact bill plus the suppressed reply legs (billing rule 3).
    pub fn legacy_scalars(&self) -> u64 {
        self.scalars + self.suppressed_scalars
    }

    /// Accumulate another ledger (integer addition — order-independent,
    /// which is what keeps sharded totals bit-identical to serial).
    pub fn merge(&mut self, other: &CommLedger) {
        if self.n_nodes == 0 && self.scalars == 0 {
            *self = other.clone();
            return;
        }
        assert_eq!(self.n_nodes, other.n_nodes, "merging ledgers of different networks");
        if self.scalars == 0 {
            self.bits_per_scalar = other.bits_per_scalar;
        } else if other.scalars > 0 {
            debug_assert_eq!(
                self.bits_per_scalar, other.bits_per_scalar,
                "merging ledgers with different payload widths"
            );
        }
        self.scalars += other.scalars;
        self.messages += other.messages;
        self.suppressed_scalars += other.suppressed_scalars;
        self.dropped_scalars += other.dropped_scalars;
        self.dropped_messages += other.dropped_messages;
        for (a, b) in self.per_node.iter_mut().zip(other.per_node.iter()) {
            *a += b;
        }
        for (a, b) in self.per_purpose.iter_mut().zip(other.per_purpose.iter()) {
            *a += b;
        }
        self.per_link.merge(&other.per_link);
    }
}

/// The live meter every [`Algorithm`](crate::algorithms::Algorithm)
/// step reports its traffic to: a [`CommLedger`] plus the current
/// iteration's link outcomes (who is gated, which request legs were
/// delivered), installed by the coordinator's impairment layer.
///
/// Scalars remain the paper's communication unit (compression ratios
/// are ratios of transmitted vector entries; index overhead is ignored
/// because selection patterns are reproducible from shared PRNG seeds);
/// billed bits add the payload-width axis on top.
#[derive(Debug, Clone)]
pub struct CommMeter {
    ledger: CommLedger,
    /// Per-node transmit gate (`true` = silent); empty = nobody gated.
    muted: Vec<bool>,
    /// Request-delivery outcomes (edge-indexed): did `src`'s estimate
    /// broadcast reach `dst` this iteration? Empty = every request
    /// delivered (the ideal-links fast path).
    delivered: LinkOutcomes,
}

impl CommMeter {
    /// A meter for `n_nodes` nodes with all counters at zero.
    pub fn new(n_nodes: usize) -> Self {
        Self {
            ledger: CommLedger::empty(n_nodes),
            muted: Vec::new(),
            delivered: LinkOutcomes::default(),
        }
    }

    /// Number of nodes the meter was sized for.
    pub fn n_nodes(&self) -> usize {
        self.ledger.n_nodes
    }

    /// Total billed scalars.
    pub fn scalars(&self) -> u64 {
        self.ledger.scalars
    }

    /// Total billed messages.
    pub fn messages(&self) -> u64 {
        self.ledger.messages
    }

    /// Total billed payload bits.
    pub fn bits(&self) -> u64 {
        self.ledger.bits()
    }

    /// The full directional ledger.
    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    /// Consume the meter, keeping only its ledger (what a finished run
    /// hands back to the scheduler).
    pub fn into_ledger(self) -> CommLedger {
        self.ledger
    }

    /// Install the payload width implied by the quantizer step Δ
    /// (0 = full precision); see [`payload_bits`].
    pub fn set_quant_step(&mut self, quant_step: f64) {
        self.ledger.bits_per_scalar = payload_bits(quant_step);
    }

    /// Install this iteration's link outcomes: the transmit-gate mask
    /// (`true` = silent) and, optionally, the edge-indexed
    /// request-delivery table (did src's broadcast reach dst?). The
    /// coordinator's impairment layer calls this before every impaired
    /// iteration; without it every send is billed (ideal links). The
    /// copy reuses the meter's buffers — allocation-free once shapes
    /// stabilise.
    pub fn set_outcomes(&mut self, muted: &[bool], delivered: Option<&LinkOutcomes>) {
        self.muted.clear();
        self.muted.extend_from_slice(muted);
        match delivered {
            Some(d) => self.delivered.copy_from(d),
            None => self.delivered.clear(),
        }
    }

    /// Remove the outcome tables (every send billed again).
    pub fn clear_outcomes(&mut self) {
        self.muted.clear();
        self.delivered.clear();
    }

    /// Record one directed message of `count` scalars from `src` to
    /// `dst` for `purpose`, applying the §9 billing rules against the
    /// installed outcome tables.
    #[inline]
    pub fn send(&mut self, src: usize, dst: usize, purpose: Purpose, count: usize) {
        if !self.muted.is_empty() && self.muted[src] {
            // Rule 1: a gated transmitter is off the air.
            return;
        }
        if purpose == Purpose::Gradient
            && !self.delivered.is_empty()
            && !self.delivered.delivered(dst, src)
        {
            // Rule 3: the soliciting broadcast dst → src never arrived,
            // so this reply was never computed or transmitted. The old
            // transmitter-only meter billed it anyway — track the gap.
            self.ledger.suppressed_scalars += count as u64;
            return;
        }
        self.bill(src, dst, purpose, count);
    }

    /// [`CommMeter::send`] for callers that already know whether the
    /// soliciting request leg was delivered (the WSN event scheduler,
    /// which draws link outcomes activation by activation instead of
    /// installing per-iteration tables).
    #[inline]
    pub fn send_solicited(
        &mut self,
        src: usize,
        dst: usize,
        purpose: Purpose,
        count: usize,
        request_delivered: bool,
    ) {
        if !self.muted.is_empty() && self.muted[src] {
            return;
        }
        if !request_delivered {
            self.ledger.suppressed_scalars += count as u64;
            return;
        }
        self.bill(src, dst, purpose, count);
    }

    /// Record a billed transmission that was erased in flight
    /// (transmitter pays, receiver gets nothing) — the bus face's lossy
    /// send. Returns whether the message was billed (i.e. actually
    /// transmitted).
    pub fn send_lossy(
        &mut self,
        src: usize,
        dst: usize,
        purpose: Purpose,
        count: usize,
        delivered: bool,
    ) -> bool {
        if !self.muted.is_empty() && self.muted[src] {
            return false;
        }
        self.bill(src, dst, purpose, count);
        if !delivered {
            self.ledger.dropped_scalars += count as u64;
            self.ledger.dropped_messages += 1;
        }
        true
    }

    #[inline]
    fn bill(&mut self, src: usize, dst: usize, purpose: Purpose, count: usize) {
        let count = count as u64;
        self.ledger.scalars += count;
        self.ledger.messages += 1;
        self.ledger.per_node[src] += count;
        self.ledger.per_purpose[purpose.index()] += count;
        self.ledger.per_link.add(src * self.ledger.n_nodes + dst, count);
    }

    /// Zero all counters and outcome tables (the payload width is kept:
    /// it is schedule-level configuration, not per-run state).
    pub fn reset(&mut self) {
        let width = self.ledger.bits_per_scalar;
        self.ledger = CommLedger::empty(self.ledger.n_nodes);
        self.ledger.bits_per_scalar = width;
        self.muted.clear();
        self.delivered.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_directionally() {
        let mut m = CommMeter::new(3);
        m.send(0, 1, Purpose::Estimate, 5);
        m.send(2, 0, Purpose::Gradient, 2);
        m.send(0, 2, Purpose::Estimate, 1);
        assert_eq!(m.scalars(), 8);
        assert_eq!(m.messages(), 3);
        assert_eq!(m.ledger().per_node, vec![6, 0, 2]);
        assert_eq!(m.ledger().link_scalars(0, 1), 5);
        assert_eq!(m.ledger().link_scalars(2, 0), 2);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Estimate), 6);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Gradient), 2);
        assert_eq!(m.bits(), 8 * 64);
        m.reset();
        assert_eq!(m.scalars(), 0);
        assert_eq!(m.ledger().per_link.iter().sum::<u64>(), 0);
    }

    #[test]
    fn muted_transmitters_are_not_billed() {
        let mut m = CommMeter::new(3);
        m.set_outcomes(&[false, true, false], None);
        m.send(0, 1, Purpose::Estimate, 4);
        m.send(1, 0, Purpose::Estimate, 4); // suppressed: gated
        m.send(2, 1, Purpose::Estimate, 4);
        assert_eq!(m.scalars(), 8);
        assert_eq!(m.messages(), 2);
        assert_eq!(m.ledger().per_node, vec![4, 0, 4]);
        // A gated node's non-transmission is not legacy over-billing:
        // the old meter's mute mask suppressed it too.
        assert_eq!(m.ledger().suppressed_scalars, 0);
        m.clear_outcomes();
        m.send(1, 0, Purpose::Estimate, 4);
        assert_eq!(m.scalars(), 12);
    }

    #[test]
    fn replies_to_dead_requests_are_suppressed_and_tracked() {
        let n = 3;
        let mut m = CommMeter::new(n);
        // Request table: node 0's broadcasts never arrive anywhere.
        let mut delivered = LinkOutcomes::fully_connected(n);
        delivered.set(0, 1, false);
        delivered.set(0, 2, false);
        m.set_outcomes(&[false; 3], Some(&delivered));
        // 0's own broadcast: billed (transmitter pays, rule 2).
        m.send(0, 1, Purpose::Estimate, 3);
        // 1's reply to 0's broadcast: the request 0 -> 1 died, so the
        // reply was never transmitted (rule 3).
        m.send(1, 0, Purpose::Gradient, 2);
        // 1's reply to 2's broadcast: request 2 -> 1 arrived.
        m.send(1, 2, Purpose::Gradient, 2);
        assert_eq!(m.scalars(), 5);
        assert_eq!(m.ledger().suppressed_scalars, 2);
        assert_eq!(m.ledger().legacy_scalars(), 7);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Gradient), 2);
    }

    #[test]
    fn quantized_payload_width() {
        assert_eq!(payload_bits(0.0), 64);
        assert_eq!(payload_bits(-1.0), 64);
        assert_eq!(payload_bits(f64::NAN), 64);
        assert_eq!(payload_bits(1e-3), 14); // 16001 levels over [-8, 8]
        assert_eq!(payload_bits(0.5), 6); // 33 levels
        assert_eq!(payload_bits(1e-30), 64); // clamped
        let mut m = CommMeter::new(2);
        m.set_quant_step(1e-3);
        m.send(0, 1, Purpose::Estimate, 10);
        assert_eq!(m.bits(), 10 * 14);
        m.reset();
        // Width survives a reset (schedule-level configuration).
        m.send(0, 1, Purpose::Estimate, 1);
        assert_eq!(m.bits(), 14);
    }

    #[test]
    fn lossy_sends_bill_the_transmitter_and_track_drops() {
        let mut m = CommMeter::new(2);
        assert!(m.send_lossy(0, 1, Purpose::Estimate, 3, true));
        assert!(m.send_lossy(0, 1, Purpose::Estimate, 3, false));
        assert_eq!(m.scalars(), 6);
        assert_eq!(m.ledger().dropped_scalars, 3);
        assert_eq!(m.ledger().dropped_messages, 1);
        m.set_outcomes(&[true, false], None);
        assert!(!m.send_lossy(0, 1, Purpose::Estimate, 3, true));
        assert_eq!(m.scalars(), 6);
    }

    #[test]
    fn solicited_face_matches_table_face() {
        let mut a = CommMeter::new(2);
        let mut delivered = LinkOutcomes::fully_connected(2);
        delivered.set(1, 0, false); // request 1 -> 0 died
        a.set_outcomes(&[false, false], Some(&delivered));
        a.send(0, 1, Purpose::Gradient, 4);
        let mut b = CommMeter::new(2);
        b.send_solicited(0, 1, Purpose::Gradient, 4, false);
        assert_eq!(a.ledger(), b.ledger());
        assert_eq!(a.ledger().suppressed_scalars, 4);
    }

    #[test]
    fn ledgers_merge_associatively() {
        let mut a = CommMeter::new(2);
        a.send(0, 1, Purpose::Estimate, 3);
        let mut b = CommMeter::new(2);
        b.send(1, 0, Purpose::Gradient, 2);
        b.send_solicited(1, 0, Purpose::Gradient, 5, false);
        let mut left = CommLedger::empty(0);
        left.merge(a.ledger());
        left.merge(b.ledger());
        let mut right = CommLedger::empty(0);
        right.merge(b.ledger());
        right.merge(a.ledger());
        assert_eq!(left.scalars, right.scalars);
        assert_eq!(left.per_link, right.per_link);
        assert_eq!(left.suppressed_scalars, 5);
        assert_eq!(left.scalars, 5);
        assert_eq!(left.messages, 2);
    }

    #[test]
    fn link_counts_dense_and_sparse_agree() {
        let mut dense = LinkCounts::Dense { n: 3, counts: vec![0; 9] };
        let mut sparse = LinkCounts::Sparse { n: 3, counts: BTreeMap::new() };
        for (idx, c) in [(1usize, 5u64), (7, 2), (1, 3), (4, 1)] {
            dense.add(idx, c);
            sparse.add(idx, c);
        }
        assert_eq!(dense, sparse);
        assert_eq!(dense.get(1), 8);
        assert_eq!(sparse.get(1), 8);
        assert_eq!(dense.iter().sum::<u64>(), sparse.iter().sum::<u64>());
        assert_eq!(
            dense.pairs().collect::<Vec<_>>(),
            vec![(1, 8), (4, 1), (7, 2)]
        );
        assert_eq!(dense.pairs().collect::<Vec<_>>(), sparse.pairs().collect::<Vec<_>>());
        // Cross-variant merge lands on the same totals.
        let mut acc = LinkCounts::for_nodes(3);
        acc.merge(&sparse);
        acc.merge(&dense);
        assert_eq!(acc.get(1), 16);
        sparse.set(1, 0);
        assert_eq!(sparse.pairs().count(), 2);
    }

    #[test]
    fn link_outcomes_default_to_delivered() {
        let g = Graph::ring(5, 1);
        let mut o = LinkOutcomes::for_graph(&g);
        assert_eq!(o.n_links(), 10);
        assert!(o.delivered(0, 1));
        // Non-edges (and self-pairs) read as delivered.
        assert!(o.delivered(0, 2));
        assert!(o.delivered(3, 3));
        o.set(0, 1, false);
        assert!(!o.delivered(0, 1));
        assert!(o.delivered(1, 0));
        // Slot addressing follows the sorted neighbour order.
        o.reset_all_true();
        o.set_row_slot(1, 0, false); // receiver 1, first in-neighbour = 0
        assert!(!o.delivered(0, 1));
        let mut copy = LinkOutcomes::default();
        assert!(copy.is_empty());
        copy.copy_from(&o);
        assert_eq!(copy, o);
        copy.clear();
        assert!(copy.is_empty());
    }
}
