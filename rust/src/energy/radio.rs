//! Radio-energy model (DESIGN.md §13): per-bit transmit/receive joule
//! costs that turn the directional ledger's billed bits into a debit on
//! the WSN charge state.
//!
//! The Table I active energies e_a are per-activation constants, so the
//! billed bits of §9 never fed back into the ENO sleep law — gating and
//! quantization savings showed up in the bill, not in the battery. With
//! a radio model attached to a scenario, every activation of node k
//! additionally debits
//!
//! ```text
//!   E_radio(k) = tx_j_per_bit · bits transmitted by k
//!              + rx_j_per_bit · bits addressed to k
//! ```
//!
//! where both bit counts are exactly the ledger's billed bits for that
//! activation (billing rules 1–3 of §9 apply unchanged: gated nodes
//! transmit nothing, erased broadcasts still cost their transmitter,
//! suppressed replies cost nobody). The debit rides on `e_a` into
//! [`NodeEnergy::cycle`](crate::energy::NodeEnergy::cycle), so the ENO
//! sleep-duration law (70) sees it as consumed active energy and the
//! activation rate responds — closing the bits → joules → activation
//! loop.
//!
//! Attribution: the whole exchange is debited from the *activating*
//! node — its own transmissions at the tx rate, the frames its
//! neighbours send it at the rx rate. Neighbour radios are modelled as
//! negligible-cost wake-on-radio receivers (DESIGN.md §13 discusses the
//! simplification). The zero-cost default draws no randomness and skips
//! the ledger snapshot entirely, so a scenario without a radio model is
//! byte-identical to the pre-radio engine.

/// Per-bit radio costs of one scenario (`[energy]` INI section).
///
/// The default is the zero-cost radio: both rates 0 J/bit, under which
/// the WSN engine takes the exact legacy code path.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RadioEnergy {
    /// Joules per transmitted payload bit (`energy.tx_j_per_bit`).
    pub tx_j_per_bit: f64,
    /// Joules per received payload bit (`energy.rx_j_per_bit`).
    pub rx_j_per_bit: f64,
}

impl RadioEnergy {
    /// The zero-cost radio (same as `Default`): no debit, legacy path.
    pub fn zero() -> Self {
        Self::default()
    }

    /// Whether this is the zero-cost radio — the gate for the WSN
    /// engine's legacy fast path (no ledger snapshots, no debit).
    pub fn is_zero(&self) -> bool {
        self.tx_j_per_bit == 0.0 && self.rx_j_per_bit == 0.0
    }

    /// Joules for an exchange of `tx_bits` transmitted and `rx_bits`
    /// received payload bits.
    pub fn cost(&self, tx_bits: u64, rx_bits: u64) -> f64 {
        tx_bits as f64 * self.tx_j_per_bit + rx_bits as f64 * self.rx_j_per_bit
    }

    /// Scenario-spec validation: both rates must be finite and
    /// non-negative.
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("energy.tx_j_per_bit", self.tx_j_per_bit),
            ("energy.rx_j_per_bit", self.rx_j_per_bit),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{name} must be finite and >= 0, got {v}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_radio_is_default_and_costs_nothing() {
        let r = RadioEnergy::default();
        assert!(r.is_zero());
        assert_eq!(r, RadioEnergy::zero());
        assert_eq!(r.cost(1_000_000, 1_000_000), 0.0);
        assert!(r.validate().is_ok());
    }

    #[test]
    fn cost_is_linear_in_bits() {
        let r = RadioEnergy { tx_j_per_bit: 2e-9, rx_j_per_bit: 1e-9 };
        assert!(!r.is_zero());
        assert!((r.cost(100, 200) - (100.0 * 2e-9 + 200.0 * 1e-9)).abs() < 1e-18);
        assert_eq!(r.cost(0, 0), 0.0);
    }

    #[test]
    fn validation_rejects_negative_and_non_finite_rates() {
        let bad = RadioEnergy { tx_j_per_bit: -1e-9, rx_j_per_bit: 0.0 };
        assert!(bad.validate().unwrap_err().contains("tx_j_per_bit"));
        let nan = RadioEnergy { tx_j_per_bit: 0.0, rx_j_per_bit: f64::NAN };
        assert!(nan.validate().unwrap_err().contains("rx_j_per_bit"));
        let inf = RadioEnergy { tx_j_per_bit: f64::INFINITY, rx_j_per_bit: 0.0 };
        assert!(inf.validate().is_err());
    }
}
