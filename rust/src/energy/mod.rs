//! ENO (Energy-Neutral Operation) substrate for the WSN experiment
//! (paper §IV, Experiment 3).
//!
//! Each agent alternates between a brief active phase (one algorithm
//! iteration + communication) and a sleep phase whose duration adapts to
//! the energy state (eq. (70)):
//!
//!   T_s = (e_c − η e_s) / (η (P_harv − P_leak) − P_sleep)
//!
//! with the consumed-energy estimate (71)  e_c = e_a + P_sleep T_{s,prev}
//! and the solar-like harvest law (72)
//!
//!   E_harv(i) = max(0, E0 sin(2π f i) + n(i)).
//!
//! Constants follow Table I (super-capacitor WSN with Bluetooth). The
//! paper's testbed is physical hardware; this module is the simulated
//! substitute (DESIGN.md §2, substitutions) implementing the same state
//! equations, so the sleep/wake dynamics match.

use crate::rng::Pcg64;

pub mod comm;
pub mod radio;

pub use comm::{payload_bits, CommLedger, CommMeter, Purpose, FULL_PRECISION_BITS, N_PURPOSES};
pub use radio::RadioEnergy;

/// Table I constants plus the harvest-law parameters.
#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// Super-capacitor capacity (F).
    pub c_s: f64,
    /// Capacitor leakage power (W).
    pub p_leak: f64,
    /// Sleep-mode power (W).
    pub p_sleep: f64,
    /// Minimal sleep duration (s).
    pub t_s_min: f64,
    /// Maximal sleep duration (s).
    pub t_s_max: f64,
    /// Minimal required voltage (V).
    pub v_ref: f64,
    /// Power-manager efficiency η.
    pub eta: f64,
    /// Harvest-law amplitude E0 (J).
    pub e0: f64,
    /// Harvest-law frequency f (Hz-like, per time unit).
    pub f: f64,
    /// Harvest-noise variance σ²_n.
    pub sigma_n2: f64,
    /// Maximum capacitor voltage (V) — caps stored energy at
    /// E = ½ C V²; 5 V for typical super-capacitor banks.
    pub v_max: f64,
}

impl Default for EnergyParams {
    /// Table I values; η = 0.8 (typical power-manager efficiency, the
    /// paper uses [37]'s manager), V_max = 5 V.
    fn default() -> Self {
        Self {
            c_s: 0.09,
            p_leak: 3.3e-6,
            p_sleep: 3.01e-5,
            t_s_min: 1.0,
            t_s_max: 300.0,
            v_ref: 3.5,
            eta: 0.8,
            e0: 0.67,
            f: 1e-5,
            sigma_n2: 1e-6,
            v_max: 5.0,
        }
    }
}

/// Per-algorithm active-phase energies e_a (J) — Table I.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActiveEnergy(pub f64);

impl ActiveEnergy {
    /// Diffusion LMS active phase (full two-way exchange).
    pub const DIFFUSION: ActiveEnergy = ActiveEnergy(8.58e-2);
    /// Reduced-communication diffusion active phase.
    pub const RCD: ActiveEnergy = ActiveEnergy(1.61e-2);
    /// Partial-diffusion active phase.
    pub const PARTIAL: ActiveEnergy = ActiveEnergy(5.4e-3);
    /// Compressed-diffusion active phase.
    pub const CD: ActiveEnergy = ActiveEnergy(7.51e-2);
    /// Doubly-compressed-diffusion active phase.
    pub const DCD: ActiveEnergy = ActiveEnergy(5.4e-3);

    /// Table I lookup by algorithm name (as reported by `Algorithm::name`).
    pub fn for_algorithm(name: &str) -> ActiveEnergy {
        match name {
            "diffusion-lms" => Self::DIFFUSION,
            "rcd" => Self::RCD,
            "partial-diffusion" => Self::PARTIAL,
            "cd" => Self::CD,
            "dcd" => Self::DCD,
            other => panic!("no Table I energy for algorithm {other:?}"),
        }
    }
}

/// Energy state of one node: super-capacitor charge + ENO sleep control.
#[derive(Debug, Clone)]
pub struct NodeEnergy {
    params: EnergyParams,
    /// Stored energy e_s (J).
    pub stored: f64,
    /// Previous sleep duration (s), used by the consumed-energy estimate.
    pub t_s_prev: f64,
    /// Per-node harvest scale (models uneven lighting on the hill).
    pub harvest_scale: f64,
}

impl NodeEnergy {
    /// A node starting at the minimum operational charge (½ C V_ref²).
    pub fn new(params: EnergyParams, harvest_scale: f64) -> Self {
        // Start with the minimum operational charge: E = ½ C V_ref².
        let stored = 0.5 * params.c_s * params.v_ref * params.v_ref;
        let t_s_prev = params.t_s_min;
        Self { params, stored, t_s_prev, harvest_scale }
    }

    /// Capacity ceiling ½ C V_max².
    pub fn capacity(&self) -> f64 {
        0.5 * self.params.c_s * self.params.v_max * self.params.v_max
    }

    /// Minimum operational energy ½ C V_ref².
    pub fn min_energy(&self) -> f64 {
        0.5 * self.params.c_s * self.params.v_ref * self.params.v_ref
    }

    /// Current capacitor voltage.
    pub fn voltage(&self) -> f64 {
        (2.0 * self.stored / self.params.c_s).sqrt()
    }

    /// Node can run an active phase only above V_ref.
    pub fn can_activate(&self) -> bool {
        self.voltage() >= self.params.v_ref
    }

    /// Harvested energy at virtual time index `i` (eq. (72)), scaled by
    /// the node's lighting factor.
    pub fn harvest(&self, i: f64, rng: &mut Pcg64) -> f64 {
        let p = &self.params;
        let noise = p.sigma_n2.sqrt() * rng.next_gaussian();
        (self.harvest_scale * (p.e0 * (2.0 * std::f64::consts::PI * p.f * i).sin() + noise))
            .max(0.0)
    }

    /// Average harvested *power* over a sleep interval starting at `i`
    /// (the P_harv of eq. (70)). Eq. (72) gives the energy E_harv,k,i
    /// collected over one full duty cycle; normalising by the maximal
    /// cycle length T_s_max puts P_harv on the scale of P_sleep
    /// (otherwise the 0.67 J amplitude would read as 0.67 W and the ENO
    /// law would never leave T_s_min — inconsistent with Fig. 4 center,
    /// where sleep periods span the full [T_s_min, T_s_max] range).
    pub fn harvest_power(&self, i: f64, rng: &mut Pcg64) -> f64 {
        self.harvest(i, rng) / self.params.t_s_max
    }

    /// One active+sleep cycle:
    ///  1. spend `e_a` (active phase),
    ///  2. compute T_s from (70)–(71),
    ///  3. sleep: spend P_sleep·T_s + P_leak·T_s, harvest P_harv·T_s·η.
    /// Returns the sleep duration chosen.
    pub fn cycle(&mut self, e_a: f64, now: f64, rng: &mut Pcg64) -> f64 {
        let p = self.params.clone();
        // Active phase.
        self.stored = (self.stored - e_a).max(0.0);
        // Consumed-energy estimate (71).
        let e_c = e_a + p.p_sleep * self.t_s_prev;
        let p_harv = self.harvest_power(now, rng);
        // Sleep-duration law (70), clamped to [T_s_min, T_s_max]. The
        // stored-energy term is the buffer *above* the ½CV_ref² reserve
        // (the energy actually spendable while staying operational); with
        // no buffer the node must sleep long enough for the harvest to
        // cover e_c — exactly the ENO condition. When the denominator is
        // non-positive (harvest below sleep+leak draw), the node sleeps
        // as long as allowed.
        let buffer = (self.stored - self.min_energy()).max(0.0);
        let denom = p.eta * (p_harv - p.p_leak) - p.p_sleep;
        let numer = e_c - p.eta * buffer;
        let mut t_s = if denom > 0.0 { numer / denom } else { p.t_s_max };
        if !t_s.is_finite() || t_s < p.t_s_min {
            t_s = p.t_s_min;
        }
        if t_s > p.t_s_max {
            t_s = p.t_s_max;
        }
        // Sleep phase bookkeeping.
        let drained = (p.p_sleep + p.p_leak) * t_s;
        let gained = p.eta * p_harv * t_s;
        self.stored = (self.stored - drained + gained).clamp(0.0, self.capacity());
        self.t_s_prev = t_s;
        t_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_constants() {
        let p = EnergyParams::default();
        assert_eq!(p.c_s, 0.09);
        assert_eq!(p.p_leak, 3.3e-6);
        assert_eq!(p.p_sleep, 3.01e-5);
        assert_eq!(p.t_s_min, 1.0);
        assert_eq!(p.t_s_max, 300.0);
        assert_eq!(p.v_ref, 3.5);
        assert_eq!(ActiveEnergy::DIFFUSION.0, 8.58e-2);
        assert_eq!(ActiveEnergy::RCD.0, 1.61e-2);
        assert_eq!(ActiveEnergy::PARTIAL.0, 5.4e-3);
        assert_eq!(ActiveEnergy::CD.0, 7.51e-2);
        assert_eq!(ActiveEnergy::DCD.0, 5.4e-3);
        assert_eq!(
            ActiveEnergy::for_algorithm("dcd"),
            ActiveEnergy::DCD
        );
    }

    #[test]
    fn harvest_law_is_nonnegative_and_periodic() {
        let node = NodeEnergy::new(EnergyParams::default(), 1.0);
        let mut rng = Pcg64::new(3, 0);
        for i in 0..200 {
            let e = node.harvest(i as f64 * 500.0, &mut rng);
            assert!(e >= 0.0);
        }
        // Positive half-period: high harvest near i = 1/(4f).
        let peak: f64 = node.harvest(0.25 / 1e-5, &mut rng);
        assert!(peak > 0.5, "peak {peak}");
        // Negative half-period clamps to zero (almost surely).
        let trough: f64 = node.harvest(0.75 / 1e-5, &mut rng);
        assert!(trough < 0.01, "trough {trough}");
    }

    #[test]
    fn sleep_clamped_to_bounds() {
        let mut node = NodeEnergy::new(EnergyParams::default(), 1.0);
        let mut rng = Pcg64::new(5, 0);
        for step in 0..100 {
            let t_s = node.cycle(ActiveEnergy::DCD.0, step as f64 * 10.0, &mut rng);
            assert!((1.0..=300.0).contains(&t_s), "t_s {t_s}");
        }
    }

    #[test]
    fn richer_harvest_shortens_sleep() {
        // In the bright phase (sin > 0) a well-lit node should reach the
        // minimum sleep duration faster than a poorly lit one.
        let mut bright = NodeEnergy::new(EnergyParams::default(), 1.0);
        let mut dark = NodeEnergy::new(EnergyParams::default(), 0.05);
        let mut rng_a = Pcg64::new(7, 0);
        let mut rng_b = Pcg64::new(7, 0);
        let mut sum_bright = 0.0;
        let mut sum_dark = 0.0;
        let mut now_a = 1000.0;
        let mut now_b = 1000.0;
        for _ in 0..50 {
            let ta = bright.cycle(ActiveEnergy::DCD.0, now_a, &mut rng_a);
            let tb = dark.cycle(ActiveEnergy::DCD.0, now_b, &mut rng_b);
            now_a += ta;
            now_b += tb;
            sum_bright += ta;
            sum_dark += tb;
        }
        assert!(sum_bright < sum_dark, "bright {sum_bright} dark {sum_dark}");
    }

    #[test]
    fn heavier_algorithm_sleeps_longer() {
        let mut heavy = NodeEnergy::new(EnergyParams::default(), 0.4);
        let mut light = NodeEnergy::new(EnergyParams::default(), 0.4);
        let mut rng_a = Pcg64::new(11, 0);
        let mut rng_b = Pcg64::new(11, 0);
        let (mut sum_h, mut sum_l) = (0.0, 0.0);
        let (mut now_h, mut now_l) = (2000.0, 2000.0);
        for _ in 0..50 {
            let th = heavy.cycle(ActiveEnergy::DIFFUSION.0, now_h, &mut rng_a);
            let tl = light.cycle(ActiveEnergy::DCD.0, now_l, &mut rng_b);
            now_h += th;
            now_l += tl;
            sum_h += th;
            sum_l += tl;
        }
        assert!(sum_h > sum_l, "heavy {sum_h} light {sum_l}");
    }

    #[test]
    fn energy_stays_in_physical_range() {
        let mut node = NodeEnergy::new(EnergyParams::default(), 1.0);
        let cap = node.capacity();
        let mut rng = Pcg64::new(13, 0);
        let mut now = 0.0;
        for _ in 0..500 {
            let t = node.cycle(ActiveEnergy::CD.0, now, &mut rng);
            now += t;
            assert!(node.stored >= 0.0 && node.stored <= cap + 1e-12);
        }
    }
}
