//! Offline shim for the `anyhow` crate (see `vendor/README.md`).
//!
//! Implements the subset this workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`]/[`bail!`] macros, [`Context`], and `Error::msg`.
//! An error is a chain of context strings: `{e}` renders the outermost
//! entry, `{e:#}` the full chain joined by `": "` (matching anyhow's
//! alternate formatting), and `{e:?}` renders the chain with a
//! `Caused by:` trailer like anyhow's Debug output.

use std::fmt;

/// Error value: a context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Prepend a context entry (the new outermost description).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, [])) => write!(f, "{head}"),
            Some((head, rest)) => {
                write!(f, "{head}\n\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    write!(f, "\n    {i}: {c}")?;
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as
// the real anyhow crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Attach context to a fallible result (anyhow's `Context` trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "loading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            bail!("bad value {}", 7)
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "bad value 7");
        fn propagates() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(propagates().is_err());
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let e = Error::msg(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }
}
