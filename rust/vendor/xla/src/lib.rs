//! Offline stub of the `xla` (PJRT / xla_extension) bindings.
//!
//! Mirrors the call surface `src/runtime/` uses so the crate builds
//! without the native xla_extension library. Every entry point that
//! would touch PJRT returns [`Error`] instead; [`runtime_available`]
//! lets callers (CLI `validate`, the xla-backed tests) detect the stub
//! and skip gracefully. Swap this path dependency for the real
//! bindings to enable the compiled engine — no caller changes needed.

use std::path::Path;

/// Error type matching the bindings' `xla::Error` usage (`Debug` is the
/// only formatting the callers rely on).
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: built with the offline `xla` stub \
         (see rust/vendor/README.md)"
            .to_string(),
    )
}

/// `false` in this stub; the real bindings report `true`.
pub fn runtime_available() -> bool {
    false
}

/// PJRT CPU client (never constructible in the stub).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Host literal (constructible, but nothing can be executed on it).
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        Err(unavailable())
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!runtime_available());
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
