//! Bench `fig3_sweep`: regenerates Fig. 3 (center/right) — steady-state
//! MSD vs compression ratio for CD and DCD — and the A2 ablation (how to
//! split a fixed budget M + M∇).
//!
//! Uses the xla engine when the exp2 artifacts exist, else the rust
//! engine (pass --fast for a shrunk sweep on the rust engine).

use dcd_lms::bench_support::{bench, fast_mode, Table};
use dcd_lms::config::Exp2Config;
use dcd_lms::experiments::{run_exp2, Engine};
use dcd_lms::runtime::Runtime;
use std::time::Duration;

fn main() {
    let fast = fast_mode();
    let mut cfg = Exp2Config::default();
    let engine;
    if fast {
        cfg.n_nodes = 16;
        cfg.dim = 16;
        cfg.runs = 4;
        cfg.iters = 800;
        cfg.cd_m_values = vec![12, 8, 4];
        cfg.dcd_pairs = vec![(8, 8), (4, 4), (2, 2), (6, 2), (2, 6)];
        engine = Engine::Rust;
    } else {
        cfg.runs = 10;
        cfg.iters = 1_500;
        // A2 ablation points: fixed budget M + M∇ = 10, different splits.
        cfg.dcd_pairs.extend_from_slice(&[(8, 2), (2, 8)]);
        engine = if !dcd_lms::runtime::xla_available() {
            Engine::Rust
        } else {
            match Runtime::open_default() {
                Ok(rt) if rt.manifest().find("dcd", "exp2").is_some() => Engine::Xla,
                _ => Engine::Rust,
            }
        };
    }

    println!(
        "== Fig. 3 (center/right): MSD vs compression ratio, N={} L={} ({engine:?} engine) ==\n",
        cfg.n_nodes, cfg.dim
    );
    let mut out = None;
    let stats = bench("exp2 sweep", 0, Duration::from_millis(1), || {
        out = Some(run_exp2(&cfg, engine, None, true).unwrap());
    });
    println!("{stats}\n");
    let out = out.unwrap();

    println!("baseline (diffusion LMS, ratio 1): {:.2} dB\n", out.baseline_db);
    let mut t = Table::new(&["algo", "ratio", "steady-state MSD (dB)"]);
    for (r, db) in &out.cd {
        t.row(&["CD".into(), format!("{r:.3}"), format!("{db:.2}")]);
    }
    for (r, db) in &out.dcd {
        t.row(&["DCD".into(), format!("{r:.3}"), format!("{db:.2}")]);
    }
    t.print();

    let cd_max = out.cd.iter().map(|p| p.0).fold(0.0, f64::max);
    let dcd_max = out.dcd.iter().map(|p| p.0).fold(0.0, f64::max);
    println!(
        "\nshape check: CD's max reachable ratio {cd_max:.2} << DCD's {dcd_max:.2} \
         (paper: CD caps at 2L/(L+M) < 2; DCD reaches 2L/(M+M∇) ≈ 20+)"
    );
}
