//! Lane-engine step throughput: the scalar `Algorithm::step` versus the
//! run-batched `BatchStep::batch_step` at SoA widths 2 / 4 / 8, over
//! N ∈ {10, 50, 320} ring networks, ideal and with a 5% i.i.d. drop
//! rebuild every iteration (DESIGN.md §14). Data is pre-sampled so the
//! rows isolate the step path — exactly the loop the lane engine
//! amortises (per-node temporaries, virtual dispatch, per-edge combiner
//! lookups). Rates are run-iterations per second: one `batch_step` at
//! width B advances B realizations, so the lanes=4 row divided by the
//! lanes=1 row is the CI speedup gate (≥ 2× at N = 50, ideal).
//!
//! Writes `BENCH_batch.json`; `--fast` / `DCD_BENCH_FAST=1` shrinks the
//! workload.

use std::time::Duration;

use dcd_lms::algorithms::{
    Algorithm, BatchCtx, BatchData, CommMeter, DiffusionLms, NetworkConfig, StepData,
};
use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::coordinator::impairments::{DropModel, Gating, ImpairmentState, LinkImpairments};
use dcd_lms::datamodel::DataModel;
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Graph, Rule};

fn net(n: usize, l: usize) -> NetworkConfig {
    let graph = Graph::ring(n, 2);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![0.01; n], dim: l }
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });
    let l = 5;
    let drop_rate = 0.05;
    let lossy = LinkImpairments {
        drop: DropModel::Iid(drop_rate),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };

    println!("== lane-engine step throughput (run-iterations/s) ==\n");
    let mut records = Vec::new();
    let mut table = Table::new(&["config", "lanes", "ns/run-iter", "run-iters/s", "speedup"]);

    for &n in &[10usize, 50, 320] {
        let network = net(n, l);
        let mut rng = Pcg64::new(3, 0);
        let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        model.sample_iteration(&mut rng, &mut u, &mut d);
        let nnz_a = network.a.nnz();
        let nnz_c = network.c.nnz();

        for (kind, imp) in [("ideal", None), ("drop5", Some(&lossy))] {
            let mut scalar_rate = 0.0f64;
            for lanes in [1usize, 2, 4, 8] {
                let name = format!("n{n}_{kind}_lanes{lanes}");
                let stats = if lanes == 1 {
                    // Scalar baseline: the round scheduler's inner body —
                    // optional impairment rebuild, then one step.
                    let mut alg = DiffusionLms::new(network.clone());
                    let mut comm = CommMeter::new(n);
                    let mut rng = Pcg64::new(5, 1);
                    let mut state = imp.map(|_| ImpairmentState::new(&network, 7, 1));
                    bench(&name, 3, budget, || {
                        if let (Some(state), Some(imp)) = (state.as_mut(), imp) {
                            state.begin_iteration(imp, &mut alg, &mut comm);
                        }
                        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
                    })
                } else {
                    // Lane engine: the same body at SoA width `lanes` —
                    // per-lane rebuild into the lane-blocked effective
                    // values, then one batch_step for all lanes.
                    let mut alg = DiffusionLms::new(network.clone());
                    let mut rngs: Vec<Pcg64> =
                        (0..lanes).map(|b| Pcg64::new(5, b as u64 + 1)).collect();
                    let mut comms: Vec<CommMeter> =
                        (0..lanes).map(|_| CommMeter::new(n)).collect();
                    let mut states: Vec<ImpairmentState> = match imp {
                        Some(_) => (0..lanes)
                            .map(|b| ImpairmentState::new(&network, 7, b as u64 + 1))
                            .collect(),
                        None => Vec::new(),
                    };
                    let mut a_vals = vec![0.0; nnz_a * lanes];
                    let mut c_vals = vec![0.0; nnz_c * lanes];
                    for b in 0..lanes {
                        a_vals[b * nnz_a..(b + 1) * nnz_a].copy_from_slice(network.a.vals());
                        c_vals[b * nnz_c..(b + 1) * nnz_c].copy_from_slice(network.c.vals());
                    }
                    let mut u_soa = vec![0.0; n * l * lanes];
                    let mut d_soa = vec![0.0; n * lanes];
                    for b in 0..lanes {
                        for (j, &x) in u.iter().enumerate() {
                            u_soa[j * lanes + b] = x;
                        }
                        for (k, &x) in d.iter().enumerate() {
                            d_soa[k * lanes + b] = x;
                        }
                    }
                    let graph = network.graph.clone();
                    let batch = alg.as_batch().expect("diffusion LMS has a batched face");
                    batch.batch_reset(lanes);
                    bench(&name, 3, budget, || {
                        if let Some(imp) = imp {
                            for (b, state) in states.iter_mut().enumerate() {
                                state.begin_iteration_lanes(
                                    imp,
                                    &graph,
                                    &[],
                                    &mut a_vals[b * nnz_a..(b + 1) * nnz_a],
                                    &mut c_vals[b * nnz_c..(b + 1) * nnz_c],
                                    &mut comms[b],
                                );
                            }
                        }
                        batch.batch_step(
                            BatchData { u: &u_soa, d: &d_soa },
                            BatchCtx { lanes, c_vals: &c_vals, a_vals: &a_vals },
                            &mut rngs,
                            &mut comms,
                        );
                    })
                };
                // One timed call advances `lanes` run-iterations.
                let ns_per_run_iter = stats.median.as_nanos() as f64 / lanes as f64;
                let rate = if ns_per_run_iter > 0.0 { 1e9 / ns_per_run_iter } else { 0.0 };
                if lanes == 1 {
                    scalar_rate = rate;
                }
                let speedup = if scalar_rate > 0.0 { rate / scalar_rate } else { 0.0 };
                table.row(&[
                    format!("N={n} {kind}"),
                    lanes.to_string(),
                    format!("{ns_per_run_iter:.0}"),
                    format!("{rate:.0}"),
                    format!("{speedup:.2}x"),
                ]);
                records.push(BenchRecord {
                    name: "batch_step".to_string(),
                    config: name,
                    median_ns: ns_per_run_iter,
                    iters_per_sec: rate,
                });
            }
        }
    }
    table.print();
    write_bench_json(
        "BENCH_batch.json",
        "lane-engine step throughput: scalar vs SoA widths 2/4/8 (diffusion LMS, ring(n,2), L=5)",
        &records,
    )
    .expect("write BENCH_batch.json");
    println!("wrote BENCH_batch.json");
}
