//! Bench `step_micro`: per-iteration microbenchmarks of the hot paths —
//! the rust engine's network step for every algorithm, the xla engine's
//! amortised per-step cost (chunked scan), and the PJRT dispatch
//! overhead (chunk length 8 vs 500). This is the L3 §Perf workhorse.

use dcd_lms::algorithms::{
    Algorithm, CommMeter, Dcd, DiffusionLms, NetworkConfig, PartialDiffusion, Rcd, StepData,
};
use dcd_lms::bench_support::{bench, fast_mode, Table};
use dcd_lms::coordinator::runner::{MonteCarlo, XlaAlgo};
use dcd_lms::datamodel::DataModel;
use dcd_lms::rng::Pcg64;
use dcd_lms::runtime::Runtime;
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::time::Duration;

fn net(n: usize, l: usize) -> NetworkConfig {
    let graph = Graph::ring(n, 2);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![0.01; n], dim: l }
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 80 } else { 400 });

    println!("== per-iteration microbenchmarks ==\n");
    let mut table = Table::new(&["hot path", "config", "ns/iteration"]);

    // --- rust engine, all algorithms, two network sizes -----------------
    for &(n, l) in &[(10usize, 5usize), (80, 40)] {
        let network = net(n, l);
        let mut rng = Pcg64::new(1, 0);
        let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        model.sample_iteration(&mut rng, &mut u, &mut d);
        let mut comm = CommMeter::new(n);

        let mut algs: Vec<Box<dyn Algorithm>> = vec![
            Box::new(DiffusionLms::new(network.clone())),
            Box::new(Dcd::cd(network.clone(), (l * 3) / 5)),
            Box::new(Dcd::new(network.clone(), l / 16 + 1, l / 16 + 1)),
            Box::new(PartialDiffusion::new(network.clone(), l / 10 + 1)),
            Box::new(Rcd::new(network.clone(), 1)),
        ];
        for alg in algs.iter_mut() {
            let name = alg.name().to_string();
            let stats = bench(&name, 3, budget, || {
                alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            });
            table.row(&[
                format!("rust {}", name),
                format!("N={n} L={l}"),
                format!("{:.0}", stats.median.as_nanos()),
            ]);
        }
    }

    // --- xla engine: amortised per-step cost via chunked scan ------------
    if !dcd_lms::runtime::xla_available() {
        println!("(xla runtime unavailable — xla rows skipped; see rust/vendor/README.md)");
    } else if let Ok(mut rt) = Runtime::open_default() {
        for config in ["smoke", "exp1", "exp3"] {
            let Some(spec) = rt.manifest().find("dcd", config).cloned() else {
                continue;
            };
            if fast && config != "smoke" {
                continue;
            }
            let (n, l, t) = (spec.n_nodes, spec.dim, spec.chunk_len);
            let network = net(n, l);
            let mut rng = Pcg64::new(2, 0);
            let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
            let mc = MonteCarlo { runs: 1, iters: t, seed: 1, record_every: 1, threads: 0 };
            let (c32, a32, mu32) = (network.c_f32(), network.a_f32(), network.mu_f32());
            let algo = XlaAlgo::Dcd { m: (l / 2).max(1), m_grad: (l / 3).max(1) };
            // Warm the compile cache outside the timed region.
            mc.run_xla(&mut rt, config, &algo, &model, &c32, &a32, &mu32).unwrap();
            let stats = bench(&format!("xla chunk {config}"), 1, budget, || {
                mc.run_xla(&mut rt, config, &algo, &model, &c32, &a32, &mu32).unwrap();
            });
            table.row(&[
                format!("xla dcd ({config})"),
                format!("N={n} L={l} T={t}"),
                format!("{:.0}", stats.median.as_nanos() as f64 / t as f64),
            ]);
        }
    } else {
        println!("(artifacts unavailable — xla rows skipped; run `make artifacts`)");
    }

    table.print();
    println!("\nnote: xla rows amortise PJRT dispatch over the scan chunk (T steps/call).");
}
