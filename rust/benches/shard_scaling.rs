//! Shard-scaling curve: wall-clock of the `fifty-node-sweep` scenario's
//! Monte-Carlo at 1 / 2 / 4 worker processes, written to
//! `BENCH_shard.json` (the perf trajectory the sharded runner is judged
//! against; DESIGN.md §8). Per-worker threads are pinned to 1 so the
//! process axis is the only parallelism being measured — on a
//! multi-core host the 4-shard row should show ≥ 2× over serial.
//!
//! Run `cargo build --release` first (the workers are spawned from the
//! `dcd-lms` binary next to this bench executable); `--fast` or
//! `DCD_BENCH_FAST=1` shrinks the workload.

use std::time::Instant;

use dcd_lms::bench_support::{fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::scenario::{find, run_scenario};

fn main() {
    let fast = fast_mode();
    let mut sc = find("fifty-node-sweep").expect("registry scenario");
    if fast {
        sc.runs = 4;
        sc.iters = 600;
    }
    // One thread per worker: the bench isolates the process axis.
    sc.threads = 1;

    // Spawn workers from the dcd-lms binary that sits next to this
    // bench executable (target/<profile>/).
    let mut bin = std::env::current_exe().expect("bench executable path");
    bin.pop(); // deps/
    bin.pop(); // release|debug
    bin.push("dcd-lms");
    if !bin.exists() {
        println!(
            "shard_scaling: worker binary {} missing — run `cargo build --release` first",
            bin.display()
        );
        return;
    }
    std::env::set_var(dcd_lms::shard::WORKER_BIN_ENV, &bin);

    let mut records = Vec::new();
    let mut table = Table::new(&["shards", "wall (s)", "runs/s", "speedup"]);
    let mut serial_secs = 0.0f64;
    for shards in [1usize, 2, 4] {
        sc.shards = shards;
        let t0 = Instant::now();
        let out = run_scenario(&sc, None, true).expect("scenario run");
        let secs = t0.elapsed().as_secs_f64();
        assert!(out.steady_db.is_finite(), "degenerate result at {shards} shards");
        if shards == 1 {
            serial_secs = secs;
        }
        let speedup = if secs > 0.0 { serial_secs / secs } else { 0.0 };
        let runs_per_sec = if secs > 0.0 { sc.runs as f64 / secs } else { 0.0 };
        table.row(&[
            shards.to_string(),
            format!("{secs:.2}"),
            format!("{runs_per_sec:.2}"),
            format!("{speedup:.2}x"),
        ]);
        records.push(BenchRecord {
            name: "fifty-node-sweep_mc".to_string(),
            config: format!("shards={shards}"),
            median_ns: secs * 1e9,
            iters_per_sec: runs_per_sec,
        });
    }
    table.print();
    write_bench_json(
        "BENCH_shard.json",
        "sharded Monte-Carlo wall-clock scaling (fifty-node-sweep, 1 thread/worker)",
        &records,
    )
    .expect("write BENCH_shard.json");
    println!("wrote BENCH_shard.json");
}
