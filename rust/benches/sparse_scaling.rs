//! Bench `sparse_scaling`: dense vs CSR on the simulator's two
//! per-iteration hot paths (DESIGN.md §10) —
//!
//! * the impairment **rebuild**: historical dense path = full N×N
//!   copies of A and C plus the per-edge erasure pass (O(N²) no matter
//!   how sparse the graph), CSR path = `ImpairmentState::begin_iteration`
//!   (one O(E) value memcpy + in-place edits);
//! * the **combine step**: weighted neighbour average of the N×L
//!   estimate block, dense column scan (O(N²·L)) vs CSR row iteration
//!   (O(E·L)).
//!
//! Emits `BENCH_sparse.json` over N ∈ {10², 10³, 10⁴, 10⁵} (grid
//! lattices, so E grows linearly with N). The dense baselines stop at
//! N = 10³: at N = 10⁴ a single dense combiner is already 800 MB.
//! CI gates on rebuild_dense / rebuild_csr ≥ 5 at N = 10³ (ci.yml).

use dcd_lms::algorithms::{CommMeter, Dcd, NetworkConfig};
use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::coordinator::impairments::{DropModel, Gating, ImpairmentState, LinkImpairments};
use dcd_lms::linalg::Mat;
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Combiner, Graph, Rule};
use std::time::Duration;

/// Largest N for which the dense baselines are materialised.
const DENSE_MAX_N: usize = 1_000;

/// Stand-in for the pre-CSR rebuild: restore both combiners with full
/// N×N copies, then walk the graph edges erasing dropped links — the
/// same per-edge draw order as the CSR path, but the copy is O(N²).
fn dense_rebuild(
    a: &mut Mat,
    c: &mut Mat,
    a0: &Mat,
    c0: &Mat,
    graph: &Graph,
    drop_prob: f64,
    rng: &mut Pcg64,
) {
    a.data_mut().copy_from_slice(a0.data());
    c.data_mut().copy_from_slice(c0.data());
    for k in 0..graph.n() {
        for &lnb in graph.neighbors(k) {
            if rng.next_bool(drop_prob) {
                let am = a[(lnb, k)];
                a[(lnb, k)] = 0.0;
                a[(k, k)] += am;
                let cm = c[(lnb, k)];
                c[(lnb, k)] = 0.0;
                c[(k, k)] += cm;
            }
        }
    }
}

/// Dense combine step: ψ ← Σ_l A[l,k]·w_l with a full column scan.
fn dense_combine(a: &Mat, w: &[f64], out: &mut [f64], l: usize) {
    let n = a.rows();
    for k in 0..n {
        let dst = &mut out[k * l..(k + 1) * l];
        dst.fill(0.0);
        for src in 0..n {
            let wgt = a[(src, k)];
            if wgt != 0.0 {
                let s = &w[src * l..(src + 1) * l];
                for (d, sv) in dst.iter_mut().zip(s) {
                    *d += wgt * sv;
                }
            }
        }
    }
}

/// CSR combine step: the neighbour iteration every algorithm now uses.
fn csr_combine(a: &Combiner, w: &[f64], out: &mut [f64], l: usize) {
    for k in 0..a.n() {
        let (cols, vals) = a.row(k);
        let dst = &mut out[k * l..(k + 1) * l];
        dst.fill(0.0);
        for (&src, &wgt) in cols.iter().zip(vals) {
            let s = &w[src * l..(src + 1) * l];
            for (d, sv) in dst.iter_mut().zip(s) {
                *d += wgt * sv;
            }
        }
    }
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });
    let dim = 4usize;
    let imp = LinkImpairments {
        drop: DropModel::Iid(0.05),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };

    println!("== dense vs CSR scaling (grid lattices, drop_prob 0.05) ==\n");
    let mut table = Table::new(&["operation", "N", "E (directed)", "median", "ns/edge"]);
    let mut records = Vec::new();

    for &(rows, cols) in &[(10usize, 10usize), (25, 40), (100, 100), (320, 320)] {
        let n = rows * cols;
        if fast && n > DENSE_MAX_N {
            continue;
        }
        let graph = Graph::grid(rows, cols);
        let e = 2 * graph.edge_count(); // directed edges
        let a = combination_matrix(&graph, Rule::Metropolis);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig {
            graph,
            c,
            a,
            mu: vec![1e-2; n],
            dim,
        };

        // --- rebuild: CSR fast path (the production coordinator loop) --
        let mut alg = Dcd::new(net.clone(), 2, 1);
        let mut comm = CommMeter::new(n);
        let mut state = ImpairmentState::new(&net, 2025, 1);
        let stats = bench("rebuild_csr", 3, budget, || {
            state.begin_iteration(&imp, &mut alg, &mut comm);
        });
        table.row(&[
            "rebuild (CSR, begin_iteration)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "rebuild_csr", &format!("N={n}")));

        // --- combine step: CSR neighbour iteration ---------------------
        let mut w = vec![0.0f64; n * dim];
        let mut rng = Pcg64::new(7, 0);
        for x in w.iter_mut() {
            *x = rng.next_gaussian();
        }
        let mut out = vec![0.0f64; n * dim];
        let a_sparse = &net.a;
        let stats = bench("combine_csr", 3, budget, || {
            csr_combine(a_sparse, &w, &mut out, dim);
            std::hint::black_box(&out);
        });
        table.row(&[
            "combine (CSR rows)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "combine_csr", &format!("N={n}")));

        // --- dense baselines (capped: O(N²) memory) --------------------
        if n > DENSE_MAX_N {
            println!(
                "(dense baselines skipped at N={n}: a dense combiner would be \
                 {:.1} MB)",
                (n * n * 8) as f64 / 1e6
            );
            continue;
        }
        let a_dense0 = net.a.to_dense();
        let c_dense0 = net.c.to_dense();
        let mut a_dense = a_dense0.clone();
        let mut c_dense = c_dense0.clone();
        let mut rng = Pcg64::new(2025, 1);
        let graph = &net.graph;
        let stats = bench("rebuild_dense", 3, budget, || {
            dense_rebuild(
                &mut a_dense,
                &mut c_dense,
                &a_dense0,
                &c_dense0,
                graph,
                imp.drop.mean_drop(),
                &mut rng,
            );
            std::hint::black_box(&a_dense);
        });
        table.row(&[
            "rebuild (dense copies)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "rebuild_dense", &format!("N={n}")));

        let stats = bench("combine_dense", 3, budget, || {
            dense_combine(&a_dense0, &w, &mut out, dim);
            std::hint::black_box(&out);
        });
        table.row(&[
            "combine (dense column scan)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "combine_dense", &format!("N={n}")));
    }
    table.print();

    match write_bench_json(
        "BENCH_sparse.json",
        "dense vs CSR hot paths on grid lattices; rebuild_dense/combine_dense = \
         pre-CSR O(N²) baselines (capped at N=1000), rebuild_csr/combine_csr = \
         O(E) production paths",
        &records,
    ) {
        Ok(()) => println!("\nwrote BENCH_sparse.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_sparse.json: {e}"),
    }

    println!(
        "\nnote: ns/edge is flat for the CSR rows (near-linear in E) and grows \
         ∝ N for the dense baselines — the gap that lets mega-grid (N = 102400) \
         run at all."
    );
}
