//! Bench `frontier_sweep`: the Pareto-frontier driver (DESIGN.md §13).
//!
//! Two costs matter when mapping a comm-cost-vs-MSD frontier:
//!
//! * **pareto_prune** — the sort-sweep that flags dominated points.
//!   O(n log n), so even a grid of 10⁵ policy points prunes in
//!   milliseconds; timed on synthetic clouds to pin that trajectory.
//! * **frontier_point** — one end-to-end grid-point evaluation (INI
//!   override → validate → Monte-Carlo run → ledger summary) on a
//!   shrunk `paper-10-node`. This is the unit the cartesian grid
//!   multiplies, so its wall time bounds any frontier invocation.
//!
//! Emits `BENCH_frontier.json`; the CI `frontier-smoke` job runs the
//! fast mode and gates on the file's presence.

use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::rng::Pcg64;
use dcd_lms::scenario::{find, frontier_scenario, pareto_front, FrontierAxis};
use std::time::Duration;

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });

    println!("== Pareto frontier: prune scaling + per-point cost ==\n");
    let mut table = Table::new(&["operation", "points", "median", "ns/point"]);
    let mut records = Vec::new();

    // --- pareto_prune on synthetic point clouds ------------------------
    for &n in &[1_000usize, 100_000] {
        if fast && n > 1_000 {
            continue;
        }
        let mut rng = Pcg64::new(42, 0);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.next_f64() * 1e6, -40.0 * rng.next_f64()))
            .collect();
        let stats = bench("pareto_prune", 3, budget, || {
            std::hint::black_box(pareto_front(&pts));
        });
        table.row(&[
            "pareto_prune (sort-sweep)".into(),
            format!("{n}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(n) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "pareto_prune", &format!("n={n}")));
    }

    // --- one grid-point evaluation, end to end -------------------------
    let mut sc = find("paper-10-node").expect("registry preset");
    sc.runs = 2;
    sc.iters = if fast { 200 } else { 1_000 };
    sc.record_every = 1;
    let axes = [FrontierAxis {
        key: "impairments.gating".into(),
        values: vec!["prob:0.5".into()],
    }];
    let stats = bench("frontier_point", 1, budget, || {
        std::hint::black_box(frontier_scenario(&sc, &axes, None, true).unwrap());
    });
    table.row(&[
        "frontier_point (paper-10-node, 1x1 grid)".into(),
        "1".into(),
        format!("{:?}", stats.median),
        format!("{:.0}", stats.per_unit(1) * 1e9),
    ]);
    records.push(BenchRecord::from_stats(
        &stats,
        "frontier_point",
        &format!("runs=2,iters={}", sc.iters),
    ));

    table.print();

    match write_bench_json(
        "BENCH_frontier.json",
        "Pareto frontier driver: pareto_prune = O(n log n) sort-sweep \
         domination flagging on synthetic (bits, msd_db) clouds; \
         frontier_point = one policy grid point end to end (INI override + \
         Monte-Carlo run) on a shrunk paper-10-node",
        &records,
    ) {
        Ok(()) => println!("\nwrote BENCH_frontier.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_frontier.json: {e}"),
    }
}
