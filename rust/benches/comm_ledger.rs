//! Bench `comm_ledger`: hot-path cost of the directional message
//! ledger (DESIGN.md §9) versus the legacy transmitter-only counter it
//! replaced, at N ∈ {10, 50, 80}.
//!
//! Three measurements per network size:
//!
//! * `step`        — one full DCD iteration billing into the ledger
//!                   (the real hot path);
//! * `ledger-pass` — one iteration's worth of `CommMeter::send` calls
//!                   alone;
//! * `legacy-pass` — the same call trace on a reconstruction of the old
//!                   undirected meter (scalars/messages/per-node only).
//!
//! The ledger's extra work per send (per-link + per-purpose counters,
//! two outcome-table branches) must stay below **5 % of the full step
//! time** on the ideal path — asserted here, so the fast-bench CI step
//! fails if the ledger ever grows into the hot loop. Emits
//! `BENCH_comm.json`.

use dcd_lms::algorithms::{Algorithm, CommMeter, Dcd, NetworkConfig, Purpose, StepData};
use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::time::Duration;

/// The pre-ledger meter, reconstructed for the comparison: undirected,
/// transmitter-only billing.
struct LegacyMeter {
    scalars: u64,
    messages: u64,
    per_node: Vec<u64>,
}

impl LegacyMeter {
    fn new(n: usize) -> Self {
        Self { scalars: 0, messages: 0, per_node: vec![0; n] }
    }

    #[inline]
    fn send(&mut self, from: usize, count: usize) {
        self.scalars += count as u64;
        self.messages += 1;
        self.per_node[from] += count as u64;
    }
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 40 } else { 200 });
    let (m, m_grad, dim) = (3usize, 1usize, 16usize);
    println!("== directional ledger hot path (DCD M=3 M∇=1, L={dim}) ==\n");
    let mut table = Table::new(&["measurement", "config", "median", "per send"]);
    let mut records = Vec::new();

    for &n in &[10usize, 50, 80] {
        if fast && n > 50 {
            continue;
        }
        let graph = Graph::ring(n, 2);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![5e-3; n], dim };
        // One iteration's send trace (src, dst, purpose, count).
        let mut trace: Vec<(usize, usize, Purpose, usize)> = Vec::new();
        for k in 0..n {
            for &nb in net.graph.neighbors(k) {
                trace.push((k, nb, Purpose::Estimate, m));
                trace.push((nb, k, Purpose::Gradient, m_grad));
            }
        }
        let sends = trace.len();
        let config = format!("N={n}");

        // The real hot path: one full DCD step billing into the ledger.
        let mut alg = Dcd::new(net.clone(), m, m_grad);
        let mut comm = CommMeter::new(n);
        let mut rng = Pcg64::new(7, 1);
        let mut u = vec![0.0f64; n * dim];
        let mut d = vec![0.0f64; n];
        for x in u.iter_mut() {
            *x = rng.next_gaussian();
        }
        for x in d.iter_mut() {
            *x = rng.next_gaussian();
        }
        let step = bench("step", 3, budget, || {
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            std::hint::black_box(&comm);
        });
        table.row(&[
            "full DCD step (ledger)".into(),
            config.clone(),
            format!("{:?}", step.median),
            String::new(),
        ]);
        records.push(BenchRecord::from_stats(&step, "step", &config));

        // The metering alone: the same send trace, ledger vs legacy.
        let mut ledger = CommMeter::new(n);
        let ledger_pass = bench("ledger-pass", 3, budget, || {
            for &(src, dst, purpose, count) in &trace {
                ledger.send(src, dst, purpose, count);
            }
            std::hint::black_box(&ledger);
        });
        let mut legacy = LegacyMeter::new(n);
        let legacy_pass = bench("legacy-pass", 3, budget, || {
            for &(src, _dst, _purpose, count) in &trace {
                legacy.send(src, count);
            }
            std::hint::black_box((&legacy.scalars, &legacy.messages, &legacy.per_node));
        });
        for (stats, name) in [(&ledger_pass, "ledger-pass"), (&legacy_pass, "legacy-pass")] {
            table.row(&[
                name.into(),
                config.clone(),
                format!("{:?}", stats.median),
                format!("{:.1} ns", stats.median.as_secs_f64() * 1e9 / sends as f64),
            ]);
            records.push(BenchRecord::from_stats(stats, name, &config));
        }

        // The acceptance gate: the ledger's *extra* metering cost per
        // iteration must stay below 5 % of the full step.
        let extra = (ledger_pass.median.as_secs_f64() - legacy_pass.median.as_secs_f64())
            .max(0.0);
        let overhead = extra / step.median.as_secs_f64();
        println!(
            "N={n}: ledger overhead {:.2}% of one step ({sends} sends)",
            overhead * 100.0
        );
        records.push(BenchRecord {
            name: "overhead-frac".into(),
            config: config.clone(),
            median_ns: extra * 1e9,
            iters_per_sec: overhead,
        });
        assert!(
            overhead < 0.05,
            "ledger overhead {:.2}% exceeds the 5% budget at N={n}",
            overhead * 100.0
        );
    }

    println!();
    table.print();
    write_bench_json(
        "BENCH_comm.json",
        "directional ledger hot-path overhead vs the legacy meter",
        &records,
    )
    .expect("write BENCH_comm.json");
    println!("\nwrote BENCH_comm.json");
}
