//! Bench `dynamics_rewire`: the incremental dynamic-network rebuild vs
//! a full combiner reconstruction (DESIGN.md §12).
//!
//! A dynamic network (churn + bursty links + adaptive combiners)
//! changes the effective combination matrices every iteration. Two ways
//! to keep them current:
//!
//! * **incremental** — `ImpairmentState::begin_iteration_dynamic`: one
//!   O(E) value memcpy plus in-place per-slot edits (churn silence,
//!   dead-edge gating, erasures, adaptive re-weighting), zero
//!   allocation — the production path;
//! * **full rebuild** — reconstruct both CSR combiners from the graph
//!   with `combination_matrix` each iteration: the naive approach a
//!   dynamic network seems to demand, allocating and re-deriving
//!   Metropolis weights from scratch.
//!
//! Emits `BENCH_dynamics.json` over grid lattices (E linear in N). The
//! CI `dynamics-smoke` job runs it in fast mode and archives the JSON.

use dcd_lms::algorithms::{CommMeter, Dcd, NetworkConfig};
use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::coordinator::dynamics::{DynamicsConfig, DynamicsState};
use dcd_lms::coordinator::impairments::{
    AdaptivePolicy, DropModel, Gating, ImpairmentState, LinkImpairments,
};
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::time::Duration;

/// Largest N the full-rebuild baseline runs at (it allocates two fresh
/// CSR combiners per iteration; the point is made well before 10⁵).
const FULL_MAX_N: usize = 10_000;

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });
    let dim = 4usize;
    // Bursty erasures + churn + adaptive combiners: every dynamic axis
    // the incremental path has to absorb per iteration.
    let imp = LinkImpairments {
        drop: DropModel::Markov { p_bad: 0.1, p_gb: 0.25, p_bg: 0.25 },
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    let dyn_cfg = DynamicsConfig {
        leave: 0.002,
        join: 0.05,
        require_connected: true,
        adaptive: AdaptivePolicy::Metropolis,
        ..DynamicsConfig::default()
    };

    println!("== incremental dynamic rebuild vs full reconstruction (grid lattices) ==\n");
    let mut table = Table::new(&["operation", "N", "E (directed)", "median", "ns/edge"]);
    let mut records = Vec::new();

    for &(rows, cols) in &[(10usize, 10usize), (25, 40), (100, 100)] {
        let n = rows * cols;
        if fast && n > 1_000 {
            continue;
        }
        let graph = Graph::grid(rows, cols);
        let e = 2 * graph.edge_count(); // directed edges
        let a = combination_matrix(&graph, Rule::Metropolis);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig {
            graph,
            c,
            a,
            mu: vec![1e-2; n],
            dim,
        };

        // --- incremental: the production dynamic path ------------------
        let mut alg = Dcd::new(net.clone(), 2, 1);
        let mut comm = CommMeter::new(n);
        let mut state = ImpairmentState::new(&net, 2025, 1);
        let mut ds = DynamicsState::new(dyn_cfg.clone(), &net, 2025, 1);
        let stats = bench("rewire_incremental", 3, budget, || {
            state.begin_iteration_dynamic(&imp, Some(&mut ds), &mut alg, &mut comm);
        });
        table.row(&[
            "rewire (incremental, begin_iteration_dynamic)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(
            &stats,
            "rewire_incremental",
            &format!("N={n}"),
        ));

        // --- full rebuild: re-derive both combiners from the graph -----
        if n > FULL_MAX_N {
            continue;
        }
        let graph = &net.graph;
        let stats = bench("rebuild_full", 3, budget, || {
            let a = combination_matrix(graph, Rule::Metropolis);
            let c = combination_matrix(graph, Rule::Metropolis);
            std::hint::black_box((&a, &c));
        });
        table.row(&[
            "rebuild (full combination_matrix x2)".into(),
            format!("{n}"),
            format!("{e}"),
            format!("{:?}", stats.median),
            format!("{:.1}", stats.per_unit(e) * 1e9),
        ]);
        records.push(BenchRecord::from_stats(&stats, "rebuild_full", &format!("N={n}")));
    }
    table.print();

    match write_bench_json(
        "BENCH_dynamics.json",
        "dynamic-network upkeep on grid lattices; rewire_incremental = O(E) \
         in-place begin_iteration_dynamic (churn + bursty links + adaptive \
         Metropolis), rebuild_full = naive per-iteration combination_matrix \
         reconstruction",
        &records,
    ) {
        Ok(()) => println!("\nwrote BENCH_dynamics.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_dynamics.json: {e}"),
    }

    println!(
        "\nnote: the incremental path also performs the per-slot erasure and \
         adaptive draws the full rebuild does not even attempt — it wins on \
         upkeep while doing strictly more work per edge."
    );
}
