//! Bench `fig3_left`: regenerates Fig. 3 (left) — theoretical and
//! simulated MSD learning curves for diffusion LMS, CD and DCD on the
//! paper's 10-node network — and reports the wall-clock cost of each
//! pipeline stage.
//!
//! Paper-shape check printed at the end: dLMS < CD < DCD steady-state
//! MSD, with theory within ~1 dB of simulation.

use dcd_lms::bench_support::{bench, fast_mode, Table};
use dcd_lms::config::Exp1Config;
use dcd_lms::experiments::{run_exp1, Engine};
use std::time::Duration;

fn main() {
    let fast = fast_mode();
    let cfg = Exp1Config {
        runs: if fast { 6 } else { 30 },
        iters: if fast { 4_000 } else { 12_000 },
        mu: 5e-3, // shrunk horizon (same steady-state structure)
        ..Exp1Config::default()
    };

    println!("== Fig. 3 (left): theory vs simulation, N=10 L=5 M=3 M∇=1 ==\n");
    let mut out = None;
    let stats = bench("exp1 full pipeline (theory + MC sim)", 0, Duration::from_millis(1), || {
        out = Some(run_exp1(&cfg, Engine::Rust, None, true).unwrap());
    });
    println!("{stats}\n");

    let out = out.unwrap();
    let mut table = Table::new(&["algorithm", "theory ss (dB)", "sim ss (dB)", "|gap| (dB)"]);
    for (label, t, s) in &out.steady {
        table.row(&[
            label.clone(),
            format!("{t:.2}"),
            format!("{s:.2}"),
            format!("{:.2}", (t - s).abs()),
        ]);
    }
    table.print();

    let ss: Vec<f64> = out.steady.iter().map(|s| s.2).collect();
    println!(
        "\nshape check: dLMS ({:.1}) <= CD ({:.1}) <= DCD ({:.1}): {}",
        ss[0],
        ss[1],
        ss[2],
        ss[0] <= ss[1] + 0.8 && ss[1] <= ss[2] + 0.8
    );
    let max_gap = out
        .steady
        .iter()
        .map(|(_, t, s)| (t - s).abs())
        .fold(0.0f64, f64::max);
    println!("model accuracy: max steady-state gap {max_gap:.2} dB (paper: ≲ 1 dB)");
}
