//! Bench `theory_ops`: the closed-form theory engine — operator
//! precomputation, one Σ-recursion application (reference vs the
//! allocation-free fast path), the noise functional, and a full
//! steady-state solve (the cost behind every theoretical curve of
//! Fig. 3 left).
//!
//! Also emits `BENCH_theory.json` — iters/sec for the Σ-recursion at
//! NL ∈ {50, 200, 800} — so future PRs have a perf trajectory to
//! regress against (see EXPERIMENTS.md §Perf).

use dcd_lms::bench_support::{bench, fast_mode, write_bench_json, BenchRecord, Table};
use dcd_lms::datamodel::DataModel;
use dcd_lms::linalg::Mat;
use dcd_lms::rng::Pcg64;
use dcd_lms::theory::{MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::time::Duration;

fn setup(n: usize, l: usize, m: usize, mg: usize) -> (TheorySetup, DataModel) {
    let graph = if n == 10 { Graph::paper_ten_node() } else { Graph::ring(n, 2) };
    let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
    let mut rng = Pcg64::new(3, 0);
    let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
    (
        TheorySetup {
            n_nodes: n,
            dim: l,
            m,
            m_grad: mg,
            c,
            mu: vec![5e-3; n],
            sigma_u2: model.sigma_u2.clone(),
            sigma_v2: model.sigma_v2.clone(),
        },
        model,
    )
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });
    println!("== theory engine (Σ-recursion) ==\n");
    let mut table = Table::new(&["operation", "config", "median"]);

    for &(n, l) in &[(10usize, 5usize), (20, 10)] {
        if fast && n > 10 {
            continue;
        }
        let (s, model) = setup(n, l, (3 * l) / 5, l / 5 + 1);
        let stats = bench("model build (precompute)", 1, budget, || {
            std::hint::black_box(MsdModel::new(s.clone()));
        });
        table.row(&[
            "precompute coefficients".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let msd = MsdModel::new(s.clone());
        let sigma = Mat::eye(n * l);
        let stats = bench("apply (reference)", 2, budget, || {
            std::hint::black_box(msd.apply(&sigma));
        });
        table.row(&[
            "Σ' = F(Σ), reference (allocating)".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let mut ws = msd.workspace();
        let mut out = Mat::zeros(n * l, n * l);
        let stats = bench("apply_into (fast path)", 2, budget, || {
            msd.apply_into(&sigma, &mut ws, &mut out);
            std::hint::black_box(&out);
        });
        table.row(&[
            "Σ' = F(Σ), apply_into (alloc-free)".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let stats = bench("noise", 2, budget, || {
            std::hint::black_box(msd.noise(&sigma));
        });
        table.row(&[
            "noise functional".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let stats = bench("steady-state", 0, Duration::from_millis(1), || {
            std::hint::black_box(msd.steady_state(&model.wo, 1e-8, 20_000));
        });
        table.row(&[
            "steady-state solve".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);
    }
    table.print();

    // --- perf trajectory: Σ-recursion at NL ∈ {50, 200, 800} ------------
    // (N, L) chosen so NL hits the targets with the paper-like L = 5;
    // `apply` is the reference allocating operator, `apply_into` the
    // production fast path. Written to BENCH_theory.json.
    let mut records = Vec::new();
    println!("\n== BENCH_theory.json sweep (Σ-recursion ops/sec) ==\n");
    let mut sweep_table = Table::new(&["op", "NL", "median", "iters/sec"]);
    for &(n, l) in &[(10usize, 5usize), (40, 5), (160, 5)] {
        let nl = n * l;
        if fast && nl > 50 {
            continue;
        }
        let (s, _) = setup(n, l, 3, 1);
        let msd = MsdModel::new(s);
        let sigma = Mat::eye(nl);

        let stats = bench("apply (reference)", 1, budget, || {
            std::hint::black_box(msd.apply(&sigma));
        });
        sweep_table.row(&[
            "apply (reference)".into(),
            format!("{nl}"),
            format!("{:?}", stats.median),
            format!("{:.2}", stats.iters_per_sec()),
        ]);
        records.push(BenchRecord::from_stats(&stats, "apply_reference", &format!("NL={nl}")));

        let mut ws = msd.workspace();
        let mut out = Mat::zeros(nl, nl);
        let stats = bench("apply_into", 1, budget, || {
            msd.apply_into(&sigma, &mut ws, &mut out);
            std::hint::black_box(&out);
        });
        sweep_table.row(&[
            "apply_into".into(),
            format!("{nl}"),
            format!("{:?}", stats.median),
            format!("{:.2}", stats.iters_per_sec()),
        ]);
        records.push(BenchRecord::from_stats(&stats, "apply_into", &format!("NL={nl}")));
    }
    sweep_table.print();
    match write_bench_json(
        "BENCH_theory.json",
        "theory engine Σ-recursion (ops/sec); apply_reference = pre-refactor allocating operator, apply_into = alloc-free fast path",
        &records,
    ) {
        Ok(()) => println!("\nwrote BENCH_theory.json ({} records)", records.len()),
        Err(e) => eprintln!("\nfailed to write BENCH_theory.json: {e}"),
    }

    println!(
        "\nnote: the (NL)²x(NL)² matrix 𝓕 of eq. (68) is never materialised — for the \
         paper's Exp. 2 shape it would be 2500²x2500²; the operator form makes the \
         theory tractable at N=10 and the xla engine covers N=50."
    );
}
