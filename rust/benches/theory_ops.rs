//! Bench `theory_ops`: the closed-form theory engine — operator
//! precomputation, one Σ-recursion application, the noise functional,
//! and a full steady-state solve (the cost behind every theoretical
//! curve of Fig. 3 left).

use dcd_lms::bench_support::{bench, fast_mode, Table};
use dcd_lms::datamodel::DataModel;
use dcd_lms::linalg::Mat;
use dcd_lms::rng::Pcg64;
use dcd_lms::theory::{MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::time::Duration;

fn setup(n: usize, l: usize, m: usize, mg: usize) -> (TheorySetup, DataModel) {
    let graph = if n == 10 { Graph::paper_ten_node() } else { Graph::ring(n, 2) };
    let c = combination_matrix(&graph, Rule::Metropolis);
    let mut rng = Pcg64::new(3, 0);
    let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
    (
        TheorySetup {
            n_nodes: n,
            dim: l,
            m,
            m_grad: mg,
            c,
            mu: vec![5e-3; n],
            sigma_u2: model.sigma_u2.clone(),
            sigma_v2: model.sigma_v2.clone(),
        },
        model,
    )
}

fn main() {
    let fast = fast_mode();
    let budget = Duration::from_millis(if fast { 60 } else { 300 });
    println!("== theory engine (Σ-recursion) ==\n");
    let mut table = Table::new(&["operation", "config", "median"]);

    for &(n, l) in &[(10usize, 5usize), (20, 10)] {
        if fast && n > 10 {
            continue;
        }
        let (s, model) = setup(n, l, (3 * l) / 5, l / 5 + 1);
        let stats = bench("model build (precompute)", 1, budget, || {
            std::hint::black_box(MsdModel::new(s.clone()));
        });
        table.row(&[
            "precompute coefficients".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let msd = MsdModel::new(s.clone());
        let sigma = Mat::eye(n * l);
        let stats = bench("apply", 2, budget, || {
            std::hint::black_box(msd.apply(&sigma));
        });
        table.row(&[
            "one Σ' = F(Σ) application".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let stats = bench("noise", 2, budget, || {
            std::hint::black_box(msd.noise(&sigma));
        });
        table.row(&[
            "noise functional".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);

        let stats = bench("steady-state", 0, Duration::from_millis(1), || {
            std::hint::black_box(msd.steady_state(&model.wo, 1e-8, 20_000));
        });
        table.row(&[
            "steady-state solve".into(),
            format!("N={n} L={l}"),
            format!("{:?}", stats.median),
        ]);
    }
    table.print();
    println!(
        "\nnote: the (NL)²x(NL)² matrix 𝓕 of eq. (68) is never materialised — for the \
         paper's Exp. 2 shape it would be 2500²x2500²; the operator form makes the \
         theory tractable at N=10 and the xla engine covers N=50."
    );
}
