//! Bench `fig4_wsn`: regenerates Fig. 4 — the energy-harvesting WSN —
//! plus Tables I/II echoes and the A1 ablation (DCD vs partial diffusion
//! at the same compression ratio: the value of gradient sharing).

use dcd_lms::bench_support::{bench, fast_mode, Table};
use dcd_lms::config::Exp3Config;
use dcd_lms::experiments::run_exp3;
use std::time::Duration;

fn main() {
    let fast = fast_mode();
    let mut cfg = Exp3Config::default();
    if fast {
        cfg.n_nodes = 24;
        cfg.dim = 16;
        cfg.radius = 0.32;
        cfg.duration = 30_000.0;
        cfg.sample_dt = 600.0;
        cfg.runs = 2;
        cfg.cd_m = 10;
        cfg.partial_m = 2;
        cfg.dcd_m = 1;
        cfg.dcd_m_grad = 1;
    } else {
        cfg.duration = 120_000.0;
        cfg.runs = 3;
    }

    println!(
        "== Fig. 4: WSN N={} L={} horizon {:.0}s ==\n",
        cfg.n_nodes, cfg.dim, cfg.duration
    );
    println!("Table I energies (J/active phase): diffusion 8.58e-2, RCD 1.61e-2,");
    println!("partial 5.4e-3, CD 7.51e-2, DCD 5.4e-3");
    println!("Table II ratios:");
    for (name, r) in cfg.ratios() {
        println!("  {name:<10} r = {r:.3}");
    }
    println!();

    let mut out = None;
    let stats = bench("exp3 WSN simulation (6 algorithms)", 0, Duration::from_millis(1), || {
        out = Some(run_exp3(&cfg, None, true).unwrap());
    });
    println!("{stats}\n");
    let out = out.unwrap();

    let mut t = Table::new(&["algorithm", "final MSD (dB)", "activations/run"]);
    for (label, db, act) in &out.summary {
        t.row(&[label.clone(), format!("{db:.2}"), format!("{act:.0}")]);
    }
    t.print();

    let get = |label: &str| out.summary.iter().find(|(l, _, _)| l == label).unwrap();
    let dcd = get("dcd (A!=I)");
    let pm = get("partial-diffusion");
    let dlms = get("diffusion-lms");
    println!("\nshape checks (paper Fig. 4 right):");
    println!(
        "  cheap algorithms beat diffusion LMS in the energy-limited regime: {}",
        dcd.1 < dlms.1
    );
    println!(
        "  A1 ablation — gradient sharing: DCD(A≠I) vs partial diffusion at equal \
         ratio: {:.2} dB vs {:.2} dB (Δ {:.2} dB, paper: DCD wins)",
        dcd.1,
        pm.1,
        pm.1 - dcd.1
    );
}
