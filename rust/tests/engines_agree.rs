//! Engine equivalence: the message-level rust engine and the
//! AOT-compiled xla engine must produce the same trajectories when
//! driven with identical data and selection patterns.
//!
//! Requires `make artifacts` (smoke config). Tolerances account for the
//! f32 (xla) vs f64 (rust) arithmetic.

use dcd_lms::algorithms::{
    Algorithm, CommMeter, Dcd, DcdMasks, NetworkConfig, PartialDiffusion, PartialMasks, Rcd,
    RcdSelection, StepData,
};
use dcd_lms::datamodel::DataModel;
use dcd_lms::rng::Pcg64;
use dcd_lms::runtime::Runtime;
use dcd_lms::topology::{combination_matrix, Graph, Rule};

struct Shared {
    n: usize,
    l: usize,
    t: usize,
    u: Vec<f32>,
    d: Vec<f32>,
    net: NetworkConfig,
    model: DataModel,
}

fn shared_inputs(rt: &Runtime, algo: &str) -> Shared {
    let spec = rt
        .manifest()
        .find(algo, "smoke")
        .unwrap_or_else(|| panic!("run `make artifacts` first ({algo}_smoke missing)"))
        .clone();
    let (n, l, t) = (spec.n_nodes, spec.dim, spec.chunk_len);
    let mut rng = Pcg64::new(1234, 0);
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    let net = NetworkConfig { graph, c, a, mu: vec![0.08; n], dim: l };
    let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
    let mut u = vec![0f32; t * n * l];
    let mut d = vec![0f32; t * n];
    model.sample_block_f32(&mut rng, t, &mut u, &mut d);
    Shared { n, l, t, u, d, net, model }
}

fn as_f64(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}

fn assert_weights_close(rust_w: &[f64], xla_w: &[f32], tag: &str) {
    for (i, (rw, xw)) in rust_w.iter().zip(xla_w.iter()).enumerate() {
        assert!(
            (rw - *xw as f64).abs() < 5e-4,
            "{tag}: weight {i} diverged: rust {rw} vs xla {xw}"
        );
    }
}

#[test]
fn dcd_engines_agree() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let s = shared_inputs(&rt, "dcd");
    let (n, l, t) = (s.n, s.l, s.t);
    let (m, mg) = (2, 1);

    let mut rng = Pcg64::new(5, 5);
    let mut h = vec![0f32; t * n * l];
    let mut q = vec![0f32; t * n * l];
    let mut scratch = Vec::new();
    for slot in 0..t * n {
        rng.fill_mask(&mut h[slot * l..(slot + 1) * l], m, &mut scratch);
        rng.fill_mask(&mut q[slot * l..(slot + 1) * l], mg, &mut scratch);
    }

    let w0 = vec![0f32; n * l];
    let (c32, a32, mu32, wo32) =
        (s.net.c_f32(), s.net.a_f32(), s.net.mu_f32(), s.model.wo_f32());
    let out = rt
        .execute_chunk("dcd_smoke", &[&w0, &s.u, &s.d, &h, &q, &c32, &a32, &mu32, &wo32])
        .unwrap();

    let mut alg = Dcd::new(s.net.clone(), m, mg);
    let mut comm = CommMeter::new(n);
    for step in 0..t {
        let masks = DcdMasks {
            h: as_f64(&h[step * n * l..(step + 1) * n * l]),
            q: as_f64(&q[step * n * l..(step + 1) * n * l]),
        };
        let u = as_f64(&s.u[step * n * l..(step + 1) * n * l]);
        let d = as_f64(&s.d[step * n..(step + 1) * n]);
        alg.step_with_masks(StepData { u: &u, d: &d }, &masks, &mut comm);
        // Per-node MSD agreement at every step.
        for k in 0..n {
            let rust_sq: f64 = (0..l)
                .map(|j| {
                    let dlt = s.model.wo[j] - alg.weights()[k * l + j];
                    dlt * dlt
                })
                .sum();
            let xla_sq = out.msd[step * n + k] as f64;
            assert!(
                (rust_sq - xla_sq).abs() < 5e-4 * rust_sq.max(1.0),
                "step {step} node {k}: rust {rust_sq} vs xla {xla_sq}"
            );
        }
    }
    assert_weights_close(alg.weights(), &out.w_final, "dcd");
}

#[test]
fn partial_engines_agree() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let s = shared_inputs(&rt, "partial");
    let (n, l, t) = (s.n, s.l, s.t);
    let m = 2;

    let mut rng = Pcg64::new(6, 6);
    let mut h = vec![0f32; t * n * l];
    let mut scratch = Vec::new();
    for slot in 0..t * n {
        rng.fill_mask(&mut h[slot * l..(slot + 1) * l], m, &mut scratch);
    }

    // Partial diffusion uses C = I.
    let mut net = s.net.clone();
    net.c = dcd_lms::topology::Combiner::eye(n);
    let w0 = vec![0f32; n * l];
    let (a32, mu32, wo32) = (net.a_f32(), net.mu_f32(), s.model.wo_f32());
    let out = rt
        .execute_chunk("partial_smoke", &[&w0, &s.u, &s.d, &h, &a32, &mu32, &wo32])
        .unwrap();

    let mut alg = PartialDiffusion::new(net, m);
    let mut comm = CommMeter::new(n);
    for step in 0..t {
        let masks = PartialMasks { h: as_f64(&h[step * n * l..(step + 1) * n * l]) };
        let u = as_f64(&s.u[step * n * l..(step + 1) * n * l]);
        let d = as_f64(&s.d[step * n..(step + 1) * n]);
        alg.step_with_masks(StepData { u: &u, d: &d }, &masks, &mut comm);
    }
    assert_weights_close(alg.weights(), &out.w_final, "partial");
}

#[test]
fn rcd_engines_agree() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let s = shared_inputs(&rt, "rcd");
    let (n, l, t) = (s.n, s.l, s.t);

    // Random neighbour selections restricted to the ring topology.
    let mut rng = Pcg64::new(7, 7);
    let mut sel = vec![0f32; t * n * n];
    let mut scratch = Vec::new();
    for ti in 0..t {
        for k in 0..n {
            let nbrs = s.net.graph.neighbors(k);
            rng.sample_indices(nbrs.len(), 1, &mut scratch);
            sel[ti * n * n + nbrs[scratch[0]] * n + k] = 1.0;
        }
    }

    let mut net = s.net.clone();
    net.c = dcd_lms::topology::Combiner::eye(n);
    let w0 = vec![0f32; n * l];
    let (a32, mu32, wo32) = (net.a_f32(), net.mu_f32(), s.model.wo_f32());
    let out = rt
        .execute_chunk("rcd_smoke", &[&w0, &s.u, &s.d, &sel, &a32, &mu32, &wo32])
        .unwrap();

    let mut alg = Rcd::new(net, 1);
    let mut comm = CommMeter::new(n);
    for step in 0..t {
        let selection = RcdSelection { s: as_f64(&sel[step * n * n..(step + 1) * n * n]) };
        let u = as_f64(&s.u[step * n * l..(step + 1) * n * l]);
        let d = as_f64(&s.d[step * n..(step + 1) * n]);
        alg.step_with_selection(StepData { u: &u, d: &d }, &selection, &mut comm);
    }
    assert_weights_close(alg.weights(), &out.w_final, "rcd");
}

#[test]
fn atc_engines_agree() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let s = shared_inputs(&rt, "atc");
    let (n, l, t) = (s.n, s.l, s.t);

    let w0 = vec![0f32; n * l];
    let (c32, a32, mu32, wo32) =
        (s.net.c_f32(), s.net.a_f32(), s.net.mu_f32(), s.model.wo_f32());
    let out = rt
        .execute_chunk("atc_smoke", &[&w0, &s.u, &s.d, &c32, &a32, &mu32, &wo32])
        .unwrap();

    let mut alg = dcd_lms::algorithms::DiffusionLms::new(s.net.clone());
    let mut comm = CommMeter::new(n);
    let mut rng = Pcg64::new(0, 0);
    for step in 0..t {
        let u = as_f64(&s.u[step * n * l..(step + 1) * n * l]);
        let d = as_f64(&s.d[step * n..(step + 1) * n]);
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
    }
    assert_weights_close(alg.weights(), &out.w_final, "atc");
}
