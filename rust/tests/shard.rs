//! End-to-end tests of the sharded multi-process Monte-Carlo runner
//! (DESIGN.md §8): byte-identical results at any `--shards × --threads`
//! combination, crash re-spawn, clean failure surfacing, and
//! malformed-frame rejection. Everything here drives the real `dcd-lms`
//! binary the way the supervisor does in production.

use std::io::Write;
use std::path::PathBuf;
use std::process::{Command, Stdio};

use dcd_lms::scenario::find;
use dcd_lms::shard::{Frame, JobKind, ShardJob};

fn binary() -> PathBuf {
    // target/<profile>/dcd-lms next to the test executable.
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release|debug
    p.push("dcd-lms");
    p
}

fn run_env(args: &[&str], envs: &[(&str, &str)]) -> (bool, String) {
    let mut cmd = Command::new(binary());
    cmd.args(args).current_dir(env!("CARGO_MANIFEST_DIR"));
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let out = cmd.output().expect("spawn dcd-lms");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

fn run(args: &[&str]) -> (bool, String) {
    run_env(args, &[])
}

fn read(path: &std::path::Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// The acceptance anchor: `scenario run --name paper-10-node --shards N`
/// writes a results CSV that is byte-identical to the serial run, for
/// N ∈ {2, 4}, including a shards × threads combination.
#[test]
fn scenario_sharded_csv_byte_identical_to_serial() {
    let dir = std::env::temp_dir().join("dcd_shard_scenario_identity");
    std::fs::remove_dir_all(&dir).ok();
    let base = [
        "scenario", "run", "--name", "paper-10-node", "--runs", "6", "--iters", "2000",
        "--quiet",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        read(&out.join("paper-10-node.csv"))
    };
    let serial = run_variant("serial", &[]);
    let s2 = run_variant("s2", &["--shards", "2"]);
    let s4 = run_variant("s4", &["--shards", "4"]);
    let s2t2 = run_variant("s2t2", &["--shards", "2", "--threads", "2"]);
    assert_eq!(serial, s2, "2 shards diverged from serial");
    assert_eq!(serial, s4, "4 shards diverged from serial");
    assert_eq!(serial, s2t2, "2 shards x 2 threads diverged from serial");
    // The JSON manifest records the shard layout (DESIGN.md §8).
    let json = read(&dir.join("s4").join("paper-10-node.json"));
    let doc = dcd_lms::jsonio::Json::parse(&json).unwrap();
    assert_eq!(doc.get("manifest").get("shards").as_usize(), Some(4));
    assert_eq!(
        doc.get("manifest").get("shard_layout").as_arr().unwrap().len(),
        4
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `exp1 --shards 2` reproduces the serial exp1 CSV byte for byte (the
/// same check CI runs on every push).
#[test]
fn exp1_sharded_csv_byte_identical_to_serial() {
    let dir = std::env::temp_dir().join("dcd_shard_exp1_identity");
    std::fs::remove_dir_all(&dir).ok();
    let serial_out = dir.join("serial");
    let shard_out = dir.join("sharded");
    let base = ["exp1", "--fast", "--runs", "4", "--iters", "1200", "--quiet"];
    let mut args: Vec<&str> = base.to_vec();
    let serial_s = serial_out.to_str().unwrap().to_string();
    args.extend_from_slice(&["--out", &serial_s]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    let mut args: Vec<&str> = base.to_vec();
    let shard_s = shard_out.to_str().unwrap().to_string();
    args.extend_from_slice(&["--out", &shard_s, "--shards", "2"]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    assert_eq!(
        read(&serial_out.join("exp1_fig3_left.csv")),
        read(&shard_out.join("exp1_fig3_left.csv")),
        "sharded exp1 diverged from serial"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `exp3 --shards 2` (the WSN job kind) reproduces the serial MSD CSV
/// byte for byte.
#[test]
fn exp3_sharded_csv_byte_identical_to_serial() {
    let dir = std::env::temp_dir().join("dcd_shard_exp3_identity");
    std::fs::remove_dir_all(&dir).ok();
    let base = ["exp3", "--fast", "--duration", "15000", "--quiet"];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        read(&out.join("exp3_fig4_right_msd.csv"))
    };
    let serial = run_variant("serial", &[]);
    let sharded = run_variant("sharded", &["--shards", "2"]);
    assert_eq!(serial, sharded, "sharded exp3 diverged from serial");
    std::fs::remove_dir_all(&dir).ok();
}

/// `scenario run --name wsn-80 --shards N`: the event-driven WSN
/// scheduler runs end-to-end with wsn-80's non-trivial impairment spec
/// (event gating + drops) across worker processes, and both the MSD CSV
/// and the per-link billed-bits ledger are byte-identical to the serial
/// run at any shards × threads combination (DESIGN.md §8, §9).
#[test]
fn wsn_scenario_sharded_billed_bits_byte_identical() {
    let dir = std::env::temp_dir().join("dcd_shard_wsn_identity");
    std::fs::remove_dir_all(&dir).ok();
    // --fast shrinks the horizon; the --set overrides shrink the
    // network so the test stays cheap. The impairments stay wsn-80's.
    let base = [
        "scenario", "run", "--name", "wsn-80", "--fast", "--runs", "4", "--quiet",
        "--set", "topology.n=20", "--set", "data.dim=8",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> (String, String) {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        (read(&out.join("wsn-80.csv")), read(&out.join("wsn-80_ledger.csv")))
    };
    let (serial_csv, serial_ledger) = run_variant("serial", &[]);
    let (s2_csv, s2_ledger) = run_variant("s2", &["--shards", "2"]);
    let (s4t2_csv, s4t2_ledger) = run_variant("s4t2", &["--shards", "4", "--threads", "2"]);
    assert_eq!(serial_csv, s2_csv, "2-shard WSN MSD diverged from serial");
    assert_eq!(serial_ledger, s2_ledger, "2-shard WSN ledger diverged from serial");
    assert_eq!(serial_csv, s4t2_csv, "4x2 WSN MSD diverged from serial");
    assert_eq!(serial_ledger, s4t2_ledger, "4x2 WSN ledger diverged from serial");
    // The ledger actually carries billed links (gating never silences
    // the whole horizon).
    assert!(serial_ledger.lines().count() > 1, "{serial_ledger}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The dynamic axes (DESIGN.md §12) through the sharded runner: every
/// dynamic preset — bursty Markov links, churn + adaptive combiners,
/// drifting optimum — produces a results CSV byte-identical to the
/// serial run at shards × threads combinations. The axes draw from
/// dedicated salted RNG streams per run, so the run split can never
/// perturb them.
#[test]
fn dynamic_presets_sharded_csv_byte_identical_to_serial() {
    let dir = std::env::temp_dir().join("dcd_shard_dynamics_identity");
    std::fs::remove_dir_all(&dir).ok();
    for name in ["bursty-geometric", "churn-grid", "tracking-ring"] {
        let base = [
            "scenario", "run", "--name", name, "--runs", "4", "--iters", "600", "--quiet",
        ];
        let run_variant = |sub: &str, extra: &[&str]| -> String {
            let out = dir.join(name).join(sub);
            let out_s = out.to_str().unwrap().to_string();
            let mut args: Vec<&str> = base.to_vec();
            args.extend_from_slice(&["--out", &out_s]);
            args.extend_from_slice(extra);
            let (ok, text) = run(&args);
            assert!(ok, "{name}/{sub}: {text}");
            read(&out.join(format!("{name}.csv")))
        };
        let serial = run_variant("serial", &[]);
        let s2 = run_variant("s2", &["--shards", "2"]);
        let s4 = run_variant("s4", &["--shards", "4"]);
        let s2t2 = run_variant("s2t2", &["--shards", "2", "--threads", "2"]);
        let s4t4 = run_variant("s4t4", &["--shards", "4", "--threads", "4"]);
        assert_eq!(serial, s2, "{name}: 2 shards diverged from serial");
        assert_eq!(serial, s4, "{name}: 4 shards diverged from serial");
        assert_eq!(serial, s2t2, "{name}: 2x2 diverged from serial");
        assert_eq!(serial, s4t4, "{name}: 4x4 diverged from serial");
    }
    // The bursty preset's manifest carries the merged link-state
    // occupancy counters (identical across layouts by integer merge).
    let json = read(
        &dir.join("bursty-geometric")
            .join("s4")
            .join("bursty-geometric.json"),
    );
    assert!(json.contains("\"linkstate\""), "manifest lost the occupancy block");
    std::fs::remove_dir_all(&dir).ok();
}

/// `drop = markov:p,1,1` redraws every sample and must be *byte*-
/// identical to the historical `drop_prob = p` spec — serial and
/// sharded alike (the acceptance criterion of DESIGN.md §12).
#[test]
fn memoryless_markov_csv_byte_identical_to_iid_prob() {
    let dir = std::env::temp_dir().join("dcd_shard_markov_iid_identity");
    std::fs::remove_dir_all(&dir).ok();
    let base = [
        "scenario", "run", "--name", "lossy-geometric", "--runs", "4", "--iters", "600",
        "--quiet",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        read(&out.join("lossy-geometric.csv"))
    };
    // lossy-geometric ships drop_prob = 0.2; the markov spec overrides
    // it with the memoryless chain at the same rate.
    let iid = run_variant("iid", &[]);
    let mk = run_variant("mk", &["--set", "impairments.drop=markov:0.2,1,1"]);
    let mk_s2 = run_variant(
        "mk_s2",
        &["--set", "impairments.drop=markov:0.2,1,1", "--shards", "2"],
    );
    let mk_s4t2 = run_variant(
        "mk_s4t2",
        &[
            "--set", "impairments.drop=markov:0.2,1,1", "--shards", "4", "--threads", "2",
        ],
    );
    assert_eq!(iid, mk, "memoryless markov diverged from prob");
    assert_eq!(iid, mk_s2, "sharded memoryless markov diverged from prob");
    assert_eq!(iid, mk_s4t2, "4x2 memoryless markov diverged from prob");
    std::fs::remove_dir_all(&dir).ok();
}

/// The frontier driver (DESIGN.md §13) through the sharded runner: the
/// full Pareto table — every grid point's objectives plus the pruning
/// verdicts — is byte-identical at 1/2/4 shards × 1/2 threads. Every
/// point runs on the deterministic runner and the prune is a pure
/// function of the point set, so the work split can never move the
/// front.
#[test]
fn frontier_csv_byte_identical_across_shards_and_threads() {
    let dir = std::env::temp_dir().join("dcd_shard_frontier_identity");
    std::fs::remove_dir_all(&dir).ok();
    let base = [
        "frontier", "--name", "priced-wsn", "--fast", "--runs", "2", "--quiet",
        "--axis", "impairments.gating=always,prob:0.5",
        "--axis", "impairments.quant_step=0,0.001",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> (String, String) {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        (
            read(&out.join("frontier_priced-wsn.csv")),
            read(&out.join("frontier_priced-wsn.json")),
        )
    };
    let (serial_csv, serial_json) = run_variant("serial", &[]);
    let (s2_csv, s2_json) = run_variant("s2", &["--shards", "2"]);
    let (s4_csv, _) = run_variant("s4", &["--shards", "4"]);
    let (s2t2_csv, _) = run_variant("s2t2", &["--shards", "2", "--threads", "2"]);
    let (s1t2_csv, _) = run_variant("s1t2", &["--threads", "2"]);
    assert_eq!(serial_csv, s2_csv, "2-shard frontier diverged from serial");
    assert_eq!(serial_csv, s4_csv, "4-shard frontier diverged from serial");
    assert_eq!(serial_csv, s2t2_csv, "2x2 frontier diverged from serial");
    assert_eq!(serial_csv, s1t2_csv, "2-thread frontier diverged from serial");
    assert_eq!(serial_json, s2_json, "frontier JSON diverged across shards");
    // 4 grid points, a header row, and a non-empty Pareto front.
    assert_eq!(serial_csv.lines().count(), 5, "{serial_csv}");
    assert!(
        serial_csv.lines().skip(1).any(|l| l.ends_with(",1")),
        "no Pareto-optimal point flagged:\n{serial_csv}"
    );
    let doc = dcd_lms::jsonio::Json::parse(&serial_json).unwrap();
    assert!(doc.get("pareto_size").as_usize().unwrap() >= 1);
    // The priced radio actually spent joules on every grid point.
    for p in doc.get("points").as_arr().unwrap() {
        assert!(p.get("radio_joules").as_f64().unwrap() > 0.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Per-leg erasures with the drop process removed are the legacy path:
/// `impairments.per_leg = true` at `drop = prob:0` writes CSV artifacts
/// byte-identical to the shared-erasure run (the zero rate
/// short-circuits both legs' draws), serial and sharded alike. With a
/// real drop rate the per-leg preset still shards byte-identically —
/// the independent reply draws ride the same per-run salted streams.
#[test]
fn per_leg_zero_drop_csv_byte_identical_to_shared_path() {
    let dir = std::env::temp_dir().join("dcd_shard_per_leg_identity");
    std::fs::remove_dir_all(&dir).ok();
    let base = [
        "scenario", "run", "--name", "lossy-geometric", "--runs", "4", "--iters", "600",
        "--quiet", "--set", "impairments.drop=prob:0",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        read(&out.join("lossy-geometric.csv"))
    };
    let shared = run_variant("shared", &[]);
    let per_leg = run_variant("per_leg", &["--set", "impairments.per_leg=true"]);
    let per_leg_s2 = run_variant(
        "per_leg_s2",
        &["--set", "impairments.per_leg=true", "--shards", "2"],
    );
    assert_eq!(shared, per_leg, "per-leg at zero drop diverged from shared");
    assert_eq!(shared, per_leg_s2, "sharded per-leg at zero drop diverged");

    // The lossy per-leg preset: serial == sharded == threaded.
    let base = [
        "scenario", "run", "--name", "per-leg-lossy", "--runs", "4", "--iters", "600",
        "--quiet",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "{sub}: {text}");
        read(&out.join("per-leg-lossy.csv"))
    };
    let serial = run_variant("lossy_serial", &[]);
    let s2 = run_variant("lossy_s2", &["--shards", "2"]);
    let s2t2 = run_variant("lossy_s2t2", &["--shards", "2", "--threads", "2"]);
    assert_eq!(serial, s2, "per-leg-lossy: 2 shards diverged from serial");
    assert_eq!(serial, s2t2, "per-leg-lossy: 2x2 diverged from serial");
    std::fs::remove_dir_all(&dir).ok();
}

/// The lane-engine acceptance battery (DESIGN.md §14): `scenario run`
/// artifacts are byte-identical at every lanes × threads × shards
/// layout — for an ideal preset, a lossy one, and the bursty-Markov
/// one. The CSV must match the serial bytes everywhere; the JSON
/// manifest must match the same-layout lanes=1 manifest (it records
/// threads and the shard layout, but never the lane width — lanes is
/// artifact-neutral by construction).
#[test]
fn laned_scenario_csv_byte_identical_across_layouts() {
    let dir = std::env::temp_dir().join("dcd_lane_identity");
    std::fs::remove_dir_all(&dir).ok();
    for name in ["paper-10-node", "lossy-geometric", "bursty-geometric"] {
        let base = [
            "scenario", "run", "--name", name, "--runs", "4", "--iters", "600", "--quiet",
        ];
        let run_variant = |sub: &str, extra: &[&str]| -> (String, String) {
            let out = dir.join(name).join(sub);
            let out_s = out.to_str().unwrap().to_string();
            let mut args: Vec<&str> = base.to_vec();
            args.extend_from_slice(&["--out", &out_s]);
            args.extend_from_slice(extra);
            let (ok, text) = run(&args);
            assert!(ok, "{name}/{sub}: {text}");
            (
                read(&out.join(format!("{name}.csv"))),
                read(&out.join(format!("{name}.json"))),
            )
        };
        let (serial_csv, _) = run_variant("serial", &[]);
        for threads in ["1", "2"] {
            for shards in ["1", "2"] {
                let mut jsons = Vec::new();
                for lanes in ["1", "2", "4"] {
                    let sub = format!("l{lanes}t{threads}s{shards}");
                    let (csv, json) = run_variant(
                        &sub,
                        &["--lanes", lanes, "--threads", threads, "--shards", shards],
                    );
                    assert_eq!(serial_csv, csv, "{name} {sub}: CSV diverged from serial");
                    jsons.push((sub, json));
                }
                // Same layout, different lane width: the full manifest
                // (ledger, linkstate, shard layout) must not move.
                let (base_sub, base_json) = &jsons[0];
                for (sub, json) in &jsons[1..] {
                    assert_eq!(
                        base_json, json,
                        "{name}: manifest diverged between {base_sub} and {sub}"
                    );
                }
            }
        }
    }
    // `--lanes auto` rides the same engine; spot-check one preset.
    let base = [
        "scenario", "run", "--name", "paper-10-node", "--runs", "4", "--iters", "600",
        "--quiet",
    ];
    let run_variant = |sub: &str, extra: &[&str]| -> String {
        let out = dir.join("auto").join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--out", &out_s]);
        args.extend_from_slice(extra);
        let (ok, text) = run(&args);
        assert!(ok, "auto/{sub}: {text}");
        read(&out.join("paper-10-node.csv"))
    };
    assert_eq!(
        run_variant("serial", &[]),
        run_variant("lanes", &["--lanes", "auto"]),
        "--lanes auto diverged from serial"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// CLI error paths for `--lanes`: 0, negatives and garbage are rejected
/// with a clear message on every front-end that accepts the flag, the
/// INI face hits the same validation, and exp3 (event-driven, never
/// run-batched) refuses the flag outright.
#[test]
fn bad_lane_counts_are_rejected() {
    let (ok, text) = run(&["exp1", "--fast", "--lanes", "0"]);
    assert!(!ok);
    assert!(text.contains("lanes 0"), "{text}");
    let (ok, text) = run(&["exp2", "--fast", "--lanes", "-3"]);
    assert!(!ok);
    assert!(text.contains("-3"), "{text}");
    let (ok, text) =
        run(&["scenario", "run", "--name", "paper-10-node", "--lanes", "banana"]);
    assert!(!ok);
    assert!(text.contains("banana"), "{text}");
    // The INI face hits the same validation (0 and overflow).
    let (ok, text) = run(&[
        "scenario", "run", "--name", "paper-10-node", "--set", "schedule.lanes=0",
        "--fast",
    ]);
    assert!(!ok);
    assert!(text.contains("lanes"), "{text}");
    let (ok, text) = run(&[
        "scenario", "run", "--name", "paper-10-node", "--set",
        "schedule.lanes=99999999999999999999", "--fast",
    ]);
    assert!(!ok);
    assert!(text.contains("lanes"), "{text}");
    // The WSN schedule has no round loop to batch.
    let (ok, text) = run(&["exp3", "--fast", "--lanes", "4"]);
    assert!(!ok);
    assert!(text.contains("event-driven"), "{text}");
    let (ok, text) = run(&[
        "scenario", "run", "--name", "wsn-80", "--fast", "--lanes", "4",
    ]);
    assert!(!ok);
    assert!(text.contains("rounds"), "{text}");
}

/// CLI error paths: `--shards 0` and negative values are rejected with
/// a clear message on every front-end that accepts the flag.
#[test]
fn bad_shard_counts_are_rejected() {
    let (ok, text) = run(&["exp1", "--fast", "--shards", "0"]);
    assert!(!ok);
    assert!(text.contains("shards"), "{text}");
    let (ok, text) = run(&["exp1", "--fast", "--shards", "-3"]);
    assert!(!ok);
    assert!(text.contains("-3"), "{text}");
    let (ok, text) =
        run(&["scenario", "run", "--name", "paper-10-node", "--shards", "0"]);
    assert!(!ok);
    assert!(text.contains("shards"), "{text}");
    let (ok, text) = run(&["exp3", "--fast", "--shards", "0"]);
    assert!(!ok);
    assert!(text.contains("shards"), "{text}");
    // The INI face hits the same validation.
    let (ok, text) = run(&[
        "scenario", "run", "--name", "paper-10-node", "--set", "schedule.shards=0",
        "--fast",
    ]);
    assert!(!ok);
    assert!(text.contains("shards"), "{text}");
}

/// A worker killed mid-run with the retry budget exhausted surfaces a
/// clean contextual error and a non-zero exit — no hang, no partial
/// results file.
#[test]
fn killed_worker_surfaces_clean_error() {
    let dir = std::env::temp_dir().join("dcd_shard_killed");
    std::fs::remove_dir_all(&dir).ok();
    let out_s = dir.to_str().unwrap().to_string();
    let (ok, text) = run_env(
        &[
            "scenario", "run", "--name", "paper-10-node", "--runs", "4", "--iters", "200",
            "--shards", "2", "--quiet", "--out", &out_s,
        ],
        &[
            (dcd_lms::shard::CRASH_RUN_ENV, "1"),
            (dcd_lms::shard::RETRIES_ENV, "0"),
        ],
    );
    assert!(!ok, "a killed worker must fail the run:\n{text}");
    assert!(text.contains("shard 0"), "{text}");
    assert!(text.contains("failed after 1 attempt"), "{text}");
    assert!(
        !dir.join("paper-10-node.csv").exists(),
        "failed run must not leave a results CSV"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A worker that crashes once is re-spawned and the run completes with
/// results byte-identical to the serial run (re-runs are deterministic).
#[test]
fn crashed_shard_is_respawned_and_result_is_exact() {
    let dir = std::env::temp_dir().join("dcd_shard_respawn");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let marker = dir.join("crash_once.marker");
    let base = [
        "scenario", "run", "--name", "paper-10-node", "--runs", "4", "--iters", "400",
        "--quiet",
    ];
    let serial_out = dir.join("serial");
    let serial_s = serial_out.to_str().unwrap().to_string();
    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--out", &serial_s]);
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    let shard_out = dir.join("sharded");
    let shard_s = shard_out.to_str().unwrap().to_string();
    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--out", &shard_s, "--shards", "2"]);
    let (ok, text) = run_env(
        &args,
        &[(dcd_lms::shard::CRASH_ONCE_ENV, marker.to_str().unwrap())],
    );
    assert!(ok, "re-spawn should recover from a single crash:\n{text}");
    assert!(marker.exists(), "the crash hook should have fired");
    assert!(text.contains("re-spawning"), "{text}");
    assert_eq!(
        read(&serial_out.join("paper-10-node.csv")),
        read(&shard_out.join("paper-10-node.csv")),
        "post-respawn result diverged from serial"
    );
    std::fs::remove_dir_all(&dir).ok();
}

fn run_worker_with_stdin(input: &str) -> (bool, String) {
    let mut child = Command::new(binary())
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn shard-worker");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(input.as_bytes())
        .expect("write job frame");
    let out = child.wait_with_output().expect("wait for shard-worker");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// Malformed frames on the worker's stdin are rejected with context and
/// a non-zero exit (never silently ignored, never a hang).
#[test]
fn worker_rejects_malformed_frames_with_context() {
    let (ok, text) = run_worker_with_stdin("this is not a frame\n");
    assert!(!ok);
    assert!(text.contains("shard protocol"), "{text}");
    let (ok, text) = run_worker_with_stdin("{\"v\":99,\"type\":\"done\",\"runs\":0}\n");
    assert!(!ok);
    assert!(text.contains("version 99"), "{text}");
    let (ok, text) = run_worker_with_stdin("{\"v\":2,\"type\":\"done\",\"runs\":0}\n");
    assert!(!ok);
    assert!(text.contains("expected a job frame"), "{text}");
    // A pre-ledger (v1) frame is rejected by version, not misread.
    let (ok, text) = run_worker_with_stdin("{\"v\":1,\"type\":\"done\",\"runs\":0}\n");
    assert!(!ok);
    assert!(text.contains("version 1"), "{text}");
    let (ok, text) = run_worker_with_stdin("");
    assert!(!ok);
    assert!(text.contains("empty input"), "{text}");
    // A syntactically valid job whose payload is garbage.
    let job = Frame::Job(ShardJob {
        kind: JobKind::Mc,
        payload: "[algorithm]\nname = quantum-lms\n".to_string(),
        run_start: 0,
        run_count: 1,
        threads: 1,
        algo_index: 0,
    });
    let (ok, text) = run_worker_with_stdin(&format!("{}\n", job.encode()));
    assert!(!ok);
    assert!(text.contains("quantum-lms"), "{text}");
    // A run block that exceeds the job's schedule.
    let sc = find("paper-10-node").unwrap();
    let job = Frame::Job(ShardJob {
        kind: JobKind::Mc,
        payload: sc.to_ini_string(),
        run_start: 99,
        run_count: 5,
        threads: 1,
        algo_index: 0,
    });
    let (ok, text) = run_worker_with_stdin(&format!("{}\n", job.encode()));
    assert!(!ok);
    assert!(text.contains("exceeds"), "{text}");
}

/// A well-formed tiny job executed directly through the worker: the
/// stream is run frames in run order followed by a done frame.
#[test]
fn worker_streams_run_frames_in_order() {
    let mut sc = find("paper-10-node").unwrap();
    sc.runs = 5;
    sc.iters = 100;
    sc.record_every = 10;
    let job = Frame::Job(ShardJob {
        kind: JobKind::Mc,
        payload: sc.to_ini_string(),
        run_start: 2,
        run_count: 2,
        threads: 1,
        algo_index: 0,
    });
    let (ok, text) = run_worker_with_stdin(&format!("{}\n", job.encode()));
    assert!(ok, "{text}");
    let frames: Vec<Frame> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Frame::decode(l).unwrap())
        .collect();
    assert_eq!(frames.len(), 3, "{text}");
    match &frames[0] {
        Frame::Run { run, .. } => assert_eq!(*run, 2),
        other => panic!("frame 0: {other:?}"),
    }
    match &frames[1] {
        Frame::Run { run, .. } => assert_eq!(*run, 3),
        other => panic!("frame 1: {other:?}"),
    }
    match &frames[2] {
        Frame::Done { runs } => assert_eq!(*runs, 2),
        other => panic!("frame 2: {other:?}"),
    }
}

/// An impostor worker that answers with garbage is caught by the
/// supervisor with a malformed-frame diagnosis (and the run fails).
#[test]
fn supervisor_rejects_impostor_worker() {
    if !std::path::Path::new("/bin/echo").exists() {
        return; // exotic platform; the unit tests still cover decode
    }
    let (ok, text) = run_env(
        &[
            "scenario", "run", "--name", "paper-10-node", "--runs", "2", "--iters", "100",
            "--shards", "2", "--quiet",
        ],
        &[
            (dcd_lms::shard::WORKER_BIN_ENV, "/bin/echo"),
            (dcd_lms::shard::RETRIES_ENV, "0"),
        ],
    );
    assert!(!ok, "an impostor worker must fail the run:\n{text}");
    assert!(text.contains("malformed"), "{text}");
}
