//! Cross-module integration: runtime + coordinator + energy + theory
//! working together, including the thread-per-agent protocol mode.

use dcd_lms::algorithms::{Algorithm, CommMeter, Dcd, NetworkConfig, StepData};
use dcd_lms::coordinator::agent::{Agent, AgentConfig};
use dcd_lms::coordinator::bus::Bus;
use dcd_lms::coordinator::runner::{MonteCarlo, XlaAlgo};
use dcd_lms::coordinator::wsn::{WsnAlgo, WsnConfig, WsnSimulation};
use dcd_lms::datamodel::DataModel;
use dcd_lms::energy::EnergyParams;
use dcd_lms::rng::Pcg64;
use dcd_lms::runtime::Runtime;
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::sync::{Arc, Barrier, Mutex};

fn ring_net(n: usize, l: usize, mu: f64) -> NetworkConfig {
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
}

/// xla engine end-to-end through the MC runner: MSD must decay.
#[test]
fn xla_monte_carlo_converges() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("run `make artifacts` (smoke)");
    let spec = rt.manifest().find("dcd", "smoke").unwrap().clone();
    let (n, l) = (spec.n_nodes, spec.dim);
    let mut rng = Pcg64::new(8, 0);
    let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
    let net = ring_net(n, l, 0.1);
    let mc = MonteCarlo { runs: 3, iters: 64, seed: 2, record_every: 1, threads: 0 };
    let res = mc
        .run_xla(
            &mut rt,
            "smoke",
            &XlaAlgo::Dcd { m: 2, m_grad: 1 },
            &model,
            &net.c_f32(),
            &net.a_f32(),
            &net.mu_f32(),
        )
        .unwrap();
    assert_eq!(res.msd.len(), 64);
    assert!(
        res.msd[63] < 0.5 * res.msd[0],
        "msd {} -> {}",
        res.msd[0],
        res.msd[63]
    );
}

/// All four algorithms through the xla engine in one session (compile
/// cache exercised); every trajectory decays.
#[test]
fn xla_all_algorithms_converge() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let spec = rt.manifest().find("dcd", "smoke").unwrap().clone();
    let (n, l) = (spec.n_nodes, spec.dim);
    let mut rng = Pcg64::new(9, 0);
    let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
    let net = ring_net(n, l, 0.1);
    dcd_lms::coordinator::runner::set_rcd_support(&net.graph);
    let mc = MonteCarlo { runs: 2, iters: 64, seed: 3, record_every: 1, threads: 0 };
    for algo in [
        XlaAlgo::Dcd { m: 2, m_grad: 1 },
        XlaAlgo::Atc,
        XlaAlgo::Rcd { m_links: 1 },
        XlaAlgo::Partial { m: 2 },
    ] {
        let res = mc
            .run_xla(&mut rt, "smoke", &algo, &model, &net.c_f32(), &net.a_f32(), &net.mu_f32())
            .unwrap();
        assert!(
            res.msd[63] < 0.7 * res.msd[0],
            "{:?}: {} -> {}",
            algo,
            res.msd[0],
            res.msd[63]
        );
    }
}

/// Thread-per-agent protocol mode: the same agent state machines that the
/// deterministic scheduler drives run under real threads with barrier
/// phases, and still reproduce the vectorised implementation exactly.
#[test]
fn threaded_agents_match_vectorized() {
    let n = 6;
    let l = 4;
    let (m, mg) = (2, 1);
    let net = ring_net(n, l, 0.07);
    let mut rng = Pcg64::new(55, 0);

    // Shared data + masks for one iteration.
    let mut u = vec![0.0; n * l];
    let mut d = vec![0.0; n];
    for x in u.iter_mut() {
        *x = rng.next_gaussian();
    }
    for dk in d.iter_mut() {
        *dk = rng.next_gaussian();
    }
    let mut h = vec![0.0; n * l];
    let mut q = vec![0.0; n * l];
    let mut scratch = Vec::new();
    let mut m32 = vec![0f32; l];
    for k in 0..n {
        rng.fill_mask(&mut m32, m, &mut scratch);
        for j in 0..l {
            h[k * l + j] = m32[j] as f64;
        }
        rng.fill_mask(&mut m32, mg, &mut scratch);
        for j in 0..l {
            q[k * l + j] = m32[j] as f64;
        }
    }

    // Vectorised reference.
    let mut reference = Dcd::new(net.clone(), m, mg);
    let mut comm = CommMeter::new(n);
    reference.step_with_masks(
        StepData { u: &u, d: &d },
        &dcd_lms::algorithms::DcdMasks { h: h.clone(), q: q.clone() },
        &mut comm,
    );

    // Threaded agents: one thread per node, barriers between phases.
    let bus = Arc::new(Bus::new(n));
    let barrier = Arc::new(Barrier::new(n));
    let results = Arc::new(Mutex::new(vec![vec![0.0; l]; n]));
    let mut handles = Vec::new();
    for k in 0..n {
        let neighbors: Vec<usize> = net.graph.neighbors(k).to_vec();
        let cfg = AgentConfig {
            id: k,
            dim: l,
            m,
            m_grad: mg,
            mu: net.mu[k],
            c_self: net.c[(k, k)],
            c_neighbors: neighbors.iter().map(|&x| net.c[(x, k)]).collect(),
            a_self: net.a[(k, k)],
            a_neighbors: neighbors.iter().map(|&x| net.a[(x, k)]).collect(),
            neighbors,
        };
        let (bus, barrier, results) = (bus.clone(), barrier.clone(), results.clone());
        let (uk, dk) = (u[k * l..(k + 1) * l].to_vec(), d[k]);
        let (hk, qk) = (h[k * l..(k + 1) * l].to_vec(), q[k * l..(k + 1) * l].to_vec());
        handles.push(std::thread::spawn(move || {
            let mut agent = Agent::new(cfg, 99);
            agent.observe(&uk, dk);
            agent.set_masks(&hk, &qk);
            agent.phase_broadcast(&bus, false);
            barrier.wait();
            agent.phase_reply(&bus);
            barrier.wait();
            agent.phase_collect(&bus);
            barrier.wait();
            agent.phase_update();
            results.lock().unwrap()[agent.id()] = agent.w.clone();
        }));
    }
    for handle in handles {
        handle.join().unwrap();
    }
    let results = results.lock().unwrap();
    for k in 0..n {
        for j in 0..l {
            let want = reference.weights()[k * l + j];
            let got = results[k][j];
            assert!(
                (want - got).abs() < 1e-12,
                "node {k} dim {j}: {want} vs {got}"
            );
        }
    }
}

/// WSN + energy + algorithm stack: Table I cost ordering shows up as an
/// activation-count ordering under identical harvest conditions.
#[test]
fn wsn_energy_ordering() {
    let n = 12;
    let l = 8;
    let mut rng = Pcg64::new(77, 0);
    let model = DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
    let graph = Graph::ring(n, 2);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    let mut activations = Vec::new();
    for algo in [
        WsnAlgo::Diffusion,
        WsnAlgo::Cd { m: 4 },
        WsnAlgo::Rcd { m_links: 1 },
        WsnAlgo::Dcd { m: 1, m_grad: 1, combine: true },
    ] {
        let cfg = WsnConfig {
            net: NetworkConfig {
                graph: graph.clone(),
                c: c.clone(),
                a: a.clone(),
                mu: vec![0.02; n],
                dim: l,
            },
            algo,
            energy: EnergyParams::default(),
            harvest_scale: vec![0.5; n],
            duration: 20_000.0,
            sample_dt: 1_000.0,
            impairments: dcd_lms::coordinator::LinkImpairments::ideal(),
            radio: dcd_lms::energy::RadioEnergy::zero(),
        };
        let res = WsnSimulation::new(cfg, model.clone()).run(5);
        activations.push((algo.label(), res.activations));
    }
    // Table I: e_diffusion > e_cd > e_rcd > e_dcd  ⇒ reverse activation order.
    for pair in activations.windows(2) {
        assert!(
            pair[0].1 <= pair[1].1,
            "{} ({}) should activate less than {} ({})",
            pair[0].0,
            pair[0].1,
            pair[1].0,
            pair[1].1
        );
    }
}

/// `run_chunks` threads the carry correctly: two chunks fed by the
/// driver equal one manual two-chunk execution.
#[test]
fn runtime_chunk_threading() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let spec = rt.manifest().find("atc", "smoke").unwrap().clone();
    let (n, l, t) = (spec.n_nodes, spec.dim, spec.chunk_len);
    let net = ring_net(n, l, 0.1);
    let mut rng = Pcg64::new(21, 0);
    let model = DataModel::paper(n, l, 1.0, 1.0, 1e-3, &mut rng);
    // Pre-generate two chunks of data.
    let mut chunks: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for _ in 0..2 {
        let mut u = vec![0f32; t * n * l];
        let mut d = vec![0f32; t * n];
        model.sample_block_f32(&mut rng, t, &mut u, &mut d);
        chunks.push((u, d));
    }
    let (c32, a32, mu32, wo32) = (net.c_f32(), net.a_f32(), net.mu_f32(), model.wo_f32());
    let w0 = vec![0f32; n * l];

    // Manual path.
    let out1 = rt
        .execute_chunk("atc_smoke", &[&w0, &chunks[0].0, &chunks[0].1, &c32, &a32, &mu32, &wo32])
        .unwrap();
    let out2 = rt
        .execute_chunk(
            "atc_smoke",
            &[&out1.w_final, &chunks[1].0, &chunks[1].1, &c32, &a32, &mu32, &wo32],
        )
        .unwrap();

    // Driver path.
    let chunks2 = chunks.clone();
    let (w_final, msd) = rt
        .run_chunks(
            "atc_smoke",
            &w0,
            2,
            move |i| vec![chunks2[i].0.clone(), chunks2[i].1.clone()],
            &[&c32, &a32, &mu32, &wo32],
        )
        .unwrap();
    assert_eq!(w_final, out2.w_final);
    let manual: Vec<f32> = out1.msd.iter().chain(out2.msd.iter()).copied().collect();
    assert_eq!(msd, manual);
}

/// The theory engine's EMSE weighting (Σ₀ = 𝓡_u) relates to MSD as the
/// paper describes: EMSE ≈ σ²_u-weighted MSD, so with uniform unit
/// regressor variances the two trajectories coincide.
#[test]
fn theory_emse_weighting() {
    use dcd_lms::theory::{MsdModel, TheorySetup};
    let n = 6;
    let l = 4;
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
    let setup = TheorySetup {
        n_nodes: n,
        dim: l,
        m: 2,
        m_grad: 1,
        c,
        mu: vec![5e-3; n],
        sigma_u2: vec![1.0; n], // unit variances ⇒ 𝓡_u = I
        sigma_v2: vec![1e-3; n],
    };
    let model = MsdModel::new(setup);
    let wo = vec![0.4, -0.2, 0.7, 0.1];
    let msd = model.trajectory(&wo, 400);
    let emse = model.trajectory_weighted(&wo, 400, Some(&vec![1.0; n]));
    for (a, b) in msd.msd.iter().zip(emse.msd.iter()) {
        assert!((a - b).abs() < 1e-12);
    }
}

/// Runtime error paths: wrong input count/shape are rejected cleanly.
#[test]
fn runtime_rejects_bad_inputs() {
    if !dcd_lms::runtime::xla_available() {
        eprintln!("skipping: xla runtime unavailable (offline `xla` stub)");
        return;
    }
    let mut rt = Runtime::open_default().expect("artifacts");
    let err = rt.execute_chunk("dcd_smoke", &[]).unwrap_err();
    assert!(format!("{err}").contains("inputs"), "{err}");
    let spec = rt.manifest().find("dcd", "smoke").unwrap().clone();
    let mut bufs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .map(|t| vec![0f32; t.num_elements()])
        .collect();
    bufs[0].pop(); // corrupt W0's length
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    let err = rt.execute_chunk("dcd_smoke", &refs).unwrap_err();
    assert!(format!("{err}").contains("expects"), "{err}");
    assert!(rt.execute_chunk("no_such_module", &[]).is_err());
}
