//! Documentation integrity: the DESIGN.md section citations sprinkled
//! through the sources (and quoted from the markdown docs) must resolve
//! to real §-numbered headings, relative markdown links must point at
//! files that exist, and `#fragment` links into markdown files must
//! name real heading anchors (GitHub slug rules). This is the in-repo
//! enforcement behind the CI markdown link-check
//! (`tools/check_md_links.py` is the standalone face of the same rules).
//!
//! Note: the citation needle is assembled at runtime so this file does
//! not match its own scanner.

use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ lives under the repo root")
        .to_path_buf()
}

/// Directories never scanned (build output, vendored deps, VCS).
const SKIP_DIRS: &[&str] = &[".git", "target", "vendor", "results", "artifacts", "__pycache__"];

/// Recursively collect files under `dir` whose name passes `keep`.
fn collect_files(dir: &Path, keep: &dyn Fn(&Path) -> bool, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if !SKIP_DIRS.contains(&name) {
                collect_files(&path, keep, out);
            }
        } else if keep(&path) {
            out.push(path);
        }
    }
}

/// Extract the token after a `§` sign: alphanumerics and dashes
/// (`"2, S10"` → `"2"`, `"Hardware-Adaptation):"` → `"Hardware-Adaptation"`).
fn section_token(after: &str) -> String {
    after
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
        .collect()
}

#[test]
fn design_md_section_citations_resolve() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repo root (cited throughout the sources)");

    // Anchors: headings that contain a § token.
    let mut anchors = Vec::new();
    for line in design.lines() {
        if !line.starts_with('#') {
            continue;
        }
        if let Some((_, rest)) = line.split_once('§') {
            let token = section_token(rest);
            if !token.is_empty() {
                anchors.push(token);
            }
        }
    }
    assert!(
        anchors.len() >= 4,
        "DESIGN.md has only {} §-numbered headings",
        anchors.len()
    );

    // Citations: every "DESIGN.md §<token>" in the rust/python sources
    // and in the markdown docs (README/EXPERIMENTS/... quote sections
    // in prose; a renumbering must not strand them). DESIGN.md itself
    // is exempt — its heading lines define the tokens.
    let mut files = Vec::new();
    let keep = |p: &Path| {
        matches!(
            p.extension().and_then(|e| e.to_str()),
            Some("rs") | Some("py") | Some("md")
        )
    };
    collect_files(&root, &keep, &mut files);
    assert!(files.len() > 20, "file walk looks broken: {} files", files.len());
    let needle = format!("{}.md §", "DESIGN");
    let mut checked = 0;
    for file in &files {
        if file.file_name().and_then(|n| n.to_str()) == Some("DESIGN.md") {
            continue;
        }
        let is_md = file.extension().and_then(|e| e.to_str()) == Some("md");
        let Ok(text) = fs::read_to_string(file) else { continue };
        for (idx, _) in text.match_indices(&needle) {
            let token = section_token(&text[idx + needle.len()..]);
            if token.is_empty() && is_md {
                // Markdown prose may quote the `§` pattern itself (same
                // semantics as the CI regex); source files stay strict —
                // an empty token there is a malformed citation.
                continue;
            }
            assert!(
                !token.is_empty() && anchors.iter().any(|a| *a == token),
                "{}: section citation `§{token}` has no matching heading in DESIGN.md \
                 (anchors: {anchors:?})",
                file.display()
            );
            checked += 1;
        }
    }
    // The repo is known to cite DESIGN.md from many modules; if this
    // drops to zero the scanner (not the docs) broke.
    assert!(checked >= 10, "only {checked} DESIGN.md § citations found");
}

/// GitHub's anchor slug for a heading: lowercase, keep alphanumerics /
/// hyphens / underscores, spaces to hyphens, everything else dropped.
fn github_slug(heading: &str) -> String {
    let mut out = String::new();
    for ch in heading.trim().chars() {
        if ch.is_alphanumeric() || ch == '_' {
            out.extend(ch.to_lowercase());
        } else if ch == ' ' || ch == '-' {
            out.push('-');
        }
    }
    out
}

/// All GitHub-style anchors of one markdown file, with the `-N`
/// suffixes GitHub appends to duplicated headings.
fn heading_anchors(text: &str) -> Vec<String> {
    let mut anchors = Vec::new();
    let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
    for line in text.lines() {
        let hashes = line.chars().take_while(|&c| c == '#').count();
        if hashes == 0 || hashes > 6 {
            continue;
        }
        let title = &line[hashes..];
        if !title.starts_with(char::is_whitespace) {
            continue;
        }
        let slug = github_slug(title);
        let n = counts.entry(slug.clone()).or_insert(0);
        anchors.push(if *n == 0 { slug.clone() } else { format!("{slug}-{n}") });
        *n += 1;
    }
    anchors
}

#[test]
fn markdown_anchor_fragments_resolve() {
    let root = repo_root();
    let mut files = Vec::new();
    let keep = |p: &Path| p.extension().and_then(|e| e.to_str()) == Some("md");
    collect_files(&root, &keep, &mut files);
    assert!(!files.is_empty());
    let mut checked = 0;
    for file in &files {
        let Ok(text) = fs::read_to_string(file) else { continue };
        let dir = file.parent().unwrap();
        for (idx, _) in text.match_indices("](") {
            let rest = &text[idx + 2..];
            let Some(end) = rest.find(')') else { continue };
            let target = &rest[..end];
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                // Same semantics as the CI regex `[^)\s]+`: a target
                // with whitespace (e.g. a markdown link title) is not
                // a checkable path.
                || target.contains(char::is_whitespace)
            {
                continue;
            }
            let Some((path_part, fragment)) = target.split_once('#') else { continue };
            if fragment.is_empty() {
                continue;
            }
            // Self-links have an empty path; only markdown targets have
            // checkable heading anchors.
            let resolved = if path_part.is_empty() {
                file.clone()
            } else {
                dir.join(path_part)
            };
            if resolved.extension().and_then(|e| e.to_str()) != Some("md") {
                continue;
            }
            let Ok(target_text) = fs::read_to_string(&resolved) else { continue };
            let anchors = heading_anchors(&target_text);
            assert!(
                anchors.iter().any(|a| a.as_str() == fragment),
                "{}: link `{target}` names no heading anchor of {} (anchors: {anchors:?})",
                file.display(),
                resolved.display()
            );
            checked += 1;
        }
    }
    // DESIGN.md's own §Hardware-Adaptation self-link plus the
    // EXPERIMENTS/README §7 deep links keep this nonzero.
    assert!(checked >= 2, "only {checked} anchored markdown links found");
}

/// The operator's handbook (docs/HANDBOOK.md) must document every CLI
/// subcommand declared in main.rs — hidden ones included — so the
/// handbook cannot silently fall behind the binary. Mirrors rule 4 of
/// `tools/check_md_links.py`.
#[test]
fn handbook_covers_every_cli_subcommand() {
    let root = repo_root();
    let handbook = fs::read_to_string(root.join("docs").join("HANDBOOK.md"))
        .expect("docs/HANDBOOK.md must exist (the operator's guide)");
    let main_rs = fs::read_to_string(root.join("rust").join("src").join("main.rs"))
        .expect("rust/src/main.rs");
    let needle = "Command::new(";
    let mut commands = Vec::new();
    for (idx, _) in main_rs.match_indices(needle) {
        let rest = main_rs[idx + needle.len()..].trim_start();
        let Some(rest) = rest.strip_prefix('"') else { continue };
        let Some(end) = rest.find('"') else { continue };
        commands.push(&rest[..end]);
    }
    assert!(
        commands.len() >= 8,
        "only {} Command::new declarations found in main.rs (scanner broke?)",
        commands.len()
    );
    for cmd in commands {
        assert!(
            handbook.contains(&format!("`{cmd}`")) || handbook.contains(&format!("`dcd-lms {cmd}")),
            "docs/HANDBOOK.md does not document the `{cmd}` subcommand"
        );
    }
}

/// Rule 5: DESIGN.md must carry the §9 ledger chapter and the ledger
/// implementation must cite it — the ledger's billing rules are
/// load-bearing documentation (the communication numbers of every
/// result file are defined there), so the section and its anchor
/// citation may not silently drift apart. Mirrors rule 5 of
/// `tools/check_md_links.py`.
#[test]
fn ledger_chapter_and_citation_are_paired() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md");
    let has_section = design
        .lines()
        .any(|l| l.starts_with('#') && l.contains("§9"));
    assert!(has_section, "DESIGN.md lost its §9 ledger chapter");
    let comm = fs::read_to_string(
        root.join("rust").join("src").join("energy").join("comm.rs"),
    )
    .expect("rust/src/energy/comm.rs (the directional ledger)");
    let needle = format!("{}.md §9", "DESIGN");
    assert!(
        comm.contains(&needle),
        "rust/src/energy/comm.rs does not cite DESIGN.md §9"
    );
}

/// Rule 6: DESIGN.md must carry the §11 serve/result-cache chapter and
/// the cache implementation must cite it — the canonical-hash and
/// cache-hit bit-identity argument lives there, and every cached byte
/// the daemon replays leans on that argument, so the chapter and its
/// anchor citation may not silently drift apart. Mirrors rule 6 of
/// `tools/check_md_links.py`.
#[test]
fn serve_chapter_and_citation_are_paired() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md");
    let has_section = design
        .lines()
        .any(|l| l.starts_with('#') && l.contains("§11"));
    assert!(has_section, "DESIGN.md lost its §11 serve/result-cache chapter");
    let cache = fs::read_to_string(
        root.join("rust").join("src").join("serve").join("cache.rs"),
    )
    .expect("rust/src/serve/cache.rs (the content-addressed result cache)");
    let needle = format!("{}.md §11", "DESIGN");
    assert!(
        cache.contains(&needle),
        "rust/src/serve/cache.rs does not cite DESIGN.md §11"
    );
}

/// Rule 7: DESIGN.md must carry the §12 dynamic-networks chapter and
/// the impairment layer must cite it — the Gilbert–Elliott closed forms
/// (stationary occupancy, burst law) that `rust/tests/dynamics.rs` pins
/// are derived there, so the chapter and its anchor citation may not
/// silently drift apart. Same shape as rules 5–6.
#[test]
fn dynamics_chapter_and_citation_are_paired() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md");
    let has_section = design
        .lines()
        .any(|l| l.starts_with('#') && l.contains("§12"));
    assert!(has_section, "DESIGN.md lost its §12 dynamic-networks chapter");
    let imp = fs::read_to_string(
        root.join("rust")
            .join("src")
            .join("coordinator")
            .join("impairments.rs"),
    )
    .expect("rust/src/coordinator/impairments.rs (the link-event layer)");
    let needle = format!("{}.md §12", "DESIGN");
    assert!(
        imp.contains(&needle),
        "rust/src/coordinator/impairments.rs does not cite DESIGN.md §12"
    );
}

/// Rule 8: DESIGN.md must carry the §13 energy-loop chapter and the
/// radio model must cite it — the activator-pays billing rule, the
/// per-leg erasure semantics, the Pareto pruning order and the
/// frontier determinism contract live there, and every frontier result
/// file is defined by them, so the chapter and its anchor citation may
/// not silently drift apart. Same shape as rules 5–7.
#[test]
fn energy_chapter_and_citation_are_paired() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md");
    let has_section = design
        .lines()
        .any(|l| l.starts_with('#') && l.contains("§13"));
    assert!(has_section, "DESIGN.md lost its §13 energy-loop chapter");
    let radio = fs::read_to_string(
        root.join("rust").join("src").join("energy").join("radio.rs"),
    )
    .expect("rust/src/energy/radio.rs (the priced radio model)");
    let needle = format!("{}.md §13", "DESIGN");
    assert!(
        radio.contains(&needle),
        "rust/src/energy/radio.rs does not cite DESIGN.md §13"
    );
}

/// Rule 9: DESIGN.md must carry the §14 lane-engine chapter and the
/// lane engine must cite it — the SoA layout, the lane-interleaving
/// bit-identity argument and the lanes × threads × shards composition
/// live there, and they are what makes `--lanes` a pure throughput
/// knob (every laned byte is pinned against the serial fold by that
/// argument), so the chapter and its anchor citation may not silently
/// drift apart. Same shape as rules 5–8.
#[test]
fn lanes_chapter_and_citation_are_paired() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md");
    let has_section = design
        .lines()
        .any(|l| l.starts_with('#') && l.contains("§14"));
    assert!(has_section, "DESIGN.md lost its §14 lane-engine chapter");
    let lanes = fs::read_to_string(
        root.join("rust")
            .join("src")
            .join("coordinator")
            .join("lanes.rs"),
    )
    .expect("rust/src/coordinator/lanes.rs (the run-batched lane engine)");
    let needle = format!("{}.md §14", "DESIGN");
    assert!(
        lanes.contains(&needle),
        "rust/src/coordinator/lanes.rs does not cite DESIGN.md §14"
    );
}

#[test]
fn relative_markdown_links_point_at_existing_files() {
    let root = repo_root();
    let mut files = Vec::new();
    let keep = |p: &Path| p.extension().and_then(|e| e.to_str()) == Some("md");
    collect_files(&root, &keep, &mut files);
    assert!(!files.is_empty());
    for file in &files {
        let Ok(text) = fs::read_to_string(file) else { continue };
        let dir = file.parent().unwrap();
        for (idx, _) in text.match_indices("](") {
            let rest = &text[idx + 2..];
            let Some(end) = rest.find(')') else { continue };
            let target = &rest[..end];
            if target.is_empty()
                || target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with('#')
                || target.starts_with("mailto:")
            {
                continue;
            }
            let path_part = target.split('#').next().unwrap();
            if path_part.is_empty() {
                continue;
            }
            let resolved = dir.join(path_part);
            assert!(
                resolved.exists(),
                "{}: markdown link `{target}` resolves to missing {}",
                file.display(),
                resolved.display()
            );
        }
    }
}
