//! Documentation integrity: the DESIGN.md section citations sprinkled
//! through the sources must resolve to real §-numbered headings, and
//! relative markdown links must point at files that exist. This is the
//! in-repo enforcement behind the CI markdown link-check
//! (`tools/check_md_links.py` is the standalone face of the same rules).
//!
//! Note: the citation needle is assembled at runtime so this file does
//! not match its own scanner.

use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ lives under the repo root")
        .to_path_buf()
}

/// Directories never scanned (build output, vendored deps, VCS).
const SKIP_DIRS: &[&str] = &[".git", "target", "vendor", "results", "artifacts", "__pycache__"];

/// Recursively collect files under `dir` whose name passes `keep`.
fn collect_files(dir: &Path, keep: &dyn Fn(&Path) -> bool, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if !SKIP_DIRS.contains(&name) {
                collect_files(&path, keep, out);
            }
        } else if keep(&path) {
            out.push(path);
        }
    }
}

/// Extract the token after a `§` sign: alphanumerics and dashes
/// (`"2, S10"` → `"2"`, `"Hardware-Adaptation):"` → `"Hardware-Adaptation"`).
fn section_token(after: &str) -> String {
    after
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
        .collect()
}

#[test]
fn design_md_section_citations_resolve() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repo root (cited throughout the sources)");

    // Anchors: headings that contain a § token.
    let mut anchors = Vec::new();
    for line in design.lines() {
        if !line.starts_with('#') {
            continue;
        }
        if let Some((_, rest)) = line.split_once('§') {
            let token = section_token(rest);
            if !token.is_empty() {
                anchors.push(token);
            }
        }
    }
    assert!(
        anchors.len() >= 4,
        "DESIGN.md has only {} §-numbered headings",
        anchors.len()
    );

    // Citations: every "DESIGN.md §<token>" in the rust/python sources
    // (the in-code contract; prose files may quote the pattern loosely).
    let mut files = Vec::new();
    let keep = |p: &Path| {
        matches!(p.extension().and_then(|e| e.to_str()), Some("rs") | Some("py"))
    };
    collect_files(&root, &keep, &mut files);
    assert!(files.len() > 20, "file walk looks broken: {} files", files.len());
    let needle = format!("{}.md §", "DESIGN");
    let mut checked = 0;
    for file in &files {
        let Ok(text) = fs::read_to_string(file) else { continue };
        for (idx, _) in text.match_indices(&needle) {
            let token = section_token(&text[idx + needle.len()..]);
            assert!(
                !token.is_empty() && anchors.iter().any(|a| *a == token),
                "{}: section citation `§{token}` has no matching heading in DESIGN.md \
                 (anchors: {anchors:?})",
                file.display()
            );
            checked += 1;
        }
    }
    // The repo is known to cite DESIGN.md from many modules; if this
    // drops to zero the scanner (not the docs) broke.
    assert!(checked >= 10, "only {checked} DESIGN.md § citations found");
}

#[test]
fn relative_markdown_links_point_at_existing_files() {
    let root = repo_root();
    let mut files = Vec::new();
    let keep = |p: &Path| p.extension().and_then(|e| e.to_str()) == Some("md");
    collect_files(&root, &keep, &mut files);
    assert!(!files.is_empty());
    for file in &files {
        let Ok(text) = fs::read_to_string(file) else { continue };
        let dir = file.parent().unwrap();
        for (idx, _) in text.match_indices("](") {
            let rest = &text[idx + 2..];
            let Some(end) = rest.find(')') else { continue };
            let target = &rest[..end];
            if target.is_empty()
                || target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with('#')
                || target.starts_with("mailto:")
            {
                continue;
            }
            let path_part = target.split('#').next().unwrap();
            if path_part.is_empty() {
                continue;
            }
            let resolved = dir.join(path_part);
            assert!(
                resolved.exists(),
                "{}: markdown link `{target}` resolves to missing {}",
                file.display(),
                resolved.display()
            );
        }
    }
}
