//! The paper's model-accuracy claim (Fig. 3 left): the closed-form
//! mean-square model must track Monte-Carlo simulation within ~1 dB at
//! steady state, for all three algorithm settings, and the transient
//! must match too.

use dcd_lms::algorithms::{Dcd, NetworkConfig};
use dcd_lms::coordinator::MonteCarlo;
use dcd_lms::datamodel::DataModel;
use dcd_lms::metrics::to_db;
use dcd_lms::rng::Pcg64;
use dcd_lms::theory::{MeanModel, MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Combiner, Graph, Rule};

fn setup(m: usize, mg: usize, mu: f64) -> (TheorySetup, NetworkConfig, DataModel) {
    let n = 10;
    let l = 5;
    let graph = Graph::paper_ten_node();
    let c = combination_matrix(&graph, Rule::Metropolis);
    let mut rng = Pcg64::new(2017, 0);
    let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
    let setup = TheorySetup {
        n_nodes: n,
        dim: l,
        m,
        m_grad: mg,
        c: c.to_dense(),
        mu: vec![mu; n],
        sigma_u2: model.sigma_u2.clone(),
        sigma_v2: model.sigma_v2.clone(),
    };
    let net = NetworkConfig { graph, c, a: Combiner::eye(n), mu: vec![mu; n], dim: l };
    (setup, net, model)
}

fn check(m: usize, mg: usize, label: &str) {
    let mu = 5e-3; // shrunk-horizon version of the paper's 1e-3
    let iters = 10_000;
    let (th_setup, net, model) = setup(m, mg, mu);
    let theory = MsdModel::new(th_setup.clone());
    let tr = theory.trajectory(&model.wo, iters);
    let mc = MonteCarlo { runs: 20, iters, seed: 3, record_every: 1, threads: 0 };
    let sim = mc.run_rust(&model, move || Box::new(Dcd::new(net.clone(), m, mg)));

    // Steady state within 1.5 dB (20 MC runs; the paper used 100).
    let t_db = to_db(tr.steady_state);
    let s_db = to_db(sim.steady_state);
    assert!(
        (t_db - s_db).abs() < 1.5,
        "{label}: steady state theory {t_db:.2} dB vs sim {s_db:.2} dB"
    );

    // Transient agreement at a few checkpoints (3 dB — single trace MC noise).
    for &i in &[200usize, 1000, 4000] {
        let t = to_db(tr.msd[i - 1]);
        let s = to_db(sim.msd[i - 1]);
        assert!(
            (t - s).abs() < 3.0,
            "{label} iter {i}: theory {t:.2} dB vs sim {s:.2} dB"
        );
    }
}

#[test]
fn dcd_theory_tracks_simulation() {
    check(3, 1, "dcd(M=3,M∇=1)");
}

#[test]
fn cd_theory_tracks_simulation() {
    check(3, 5, "cd(M=3)");
}

#[test]
fn diffusion_theory_tracks_simulation() {
    check(5, 5, "diffusion-lms");
}

#[test]
fn mean_stability_bound_separates_regimes() {
    // μ below the paper bound (38)-(39) ⇒ ρ(B) < 1; far above ⇒ unstable.
    let (s, _, _) = setup(3, 1, 0.0);
    let bounds = MeanModel::new(s.clone()).paper_mu_bounds();
    let bound = bounds.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut ok = s.clone();
    ok.mu = vec![0.4 * bound; 10];
    assert!(MeanModel::new(ok).is_mean_stable());
    let mut bad = s;
    bad.mu = vec![4.0 * bound; 10];
    assert!(!MeanModel::new(bad).is_mean_stable());
}

#[test]
fn compression_ordering_matches_paper() {
    // Fig. 3 (left): diffusion LMS outperforms CD outperforms DCD.
    let mu = 5e-3;
    let ss = |m: usize, mg: usize| {
        let (s, _, model) = setup(m, mg, mu);
        to_db(MsdModel::new(s).steady_state(&model.wo, 1e-10, 30_000).0)
    };
    let dlms = ss(5, 5);
    let cd = ss(3, 5);
    let dcd = ss(3, 1);
    assert!(dlms < cd, "dLMS {dlms} < CD {cd}");
    assert!(cd < dcd, "CD {cd} < DCD {dcd}");
}
