//! End-to-end tests of the resident serve daemon and its
//! content-addressed result cache (DESIGN.md §11): resubmits are
//! byte-identical cache hits with zero simulation work, single-key
//! perturbations miss, semantically identical INIs share an entry, a
//! worker crash under the daemon converges to the uncrashed bytes, and
//! a client disconnect mid-stream never loses the job.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use dcd_lms::config::IniDoc;
use dcd_lms::scenario::{find, Scenario};
use dcd_lms::serve::{canonical_scenario, job_key, SessionFrame};

fn binary() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release|debug
    p.push("dcd-lms");
    p
}

struct DaemonHandle {
    child: Child,
    addr: String,
    stdout: BufReader<std::process::ChildStdout>,
}

impl DaemonHandle {
    /// Drain the queue, stop the daemon, and assert a clean exit.
    fn stop(mut self) {
        let out = Command::new(binary())
            .args(["serve", "--stop", &self.addr])
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .expect("spawn serve --stop");
        assert!(
            out.status.success(),
            "serve --stop failed: {}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let status = self.child.wait().expect("wait for daemon");
        assert!(status.success(), "daemon exited non-zero");
        let mut rest = String::new();
        let _ = self.stdout.read_to_string(&mut rest);
        assert!(rest.contains("serve: stopped"), "{rest}");
    }
}

use std::io::Read as _;

/// Spawn `dcd-lms serve --listen 127.0.0.1:0 ...` and parse the bound
/// address from its banner line.
fn spawn_daemon(cache: &Path, extra: &[&str], envs: &[(&str, &str)]) -> DaemonHandle {
    let mut cmd = Command::new(binary());
    cmd.args(["serve", "--listen", "127.0.0.1:0", "--cache", cache.to_str().unwrap()])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"));
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("spawn dcd-lms serve");
    let mut stdout = BufReader::new(child.stdout.take().expect("daemon stdout"));
    let mut banner = String::new();
    stdout.read_line(&mut banner).expect("read serve banner");
    let addr = banner
        .trim()
        .strip_prefix("serve: listening on ")
        .unwrap_or_else(|| panic!("unexpected banner {banner:?}"))
        .to_string();
    DaemonHandle { child, addr, stdout }
}

/// One raw v3 session over TCP.
struct Session {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Session {
    fn open(addr: &str) -> Session {
        let stream = TcpStream::connect(addr).expect("connect to daemon");
        let writer = stream.try_clone().expect("clone session stream");
        Session { reader: BufReader::new(stream), writer }
    }

    fn send(&mut self, frame: &SessionFrame) {
        writeln!(self.writer, "{}", frame.encode()).expect("send frame");
        self.writer.flush().expect("flush frame");
    }

    fn recv(&mut self) -> SessionFrame {
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).expect("read frame");
            assert!(n > 0, "daemon closed the session unexpectedly");
            if line.trim().is_empty() {
                continue;
            }
            return SessionFrame::decode(&line).expect("daemon frame decodes");
        }
    }

    /// Submit with wait and read frames through the terminal result.
    fn submit_and_wait(&mut self, spec: &str) -> (u64, String, bool, String, String, String) {
        self.send(&SessionFrame::Submit { spec: spec.to_string(), wait: true });
        let (job, key0, _) = match self.recv() {
            SessionFrame::Accepted { job, key, cached } => (job, key, cached),
            other => panic!("expected accepted, got {other:?}"),
        };
        loop {
            match self.recv() {
                SessionFrame::Progress { .. } => continue,
                SessionFrame::Result { job: j, key, cached, csv, json, ledger_csv, .. } => {
                    assert_eq!(j, job);
                    assert_eq!(key, key0, "result key differs from accepted key");
                    return (job, key, cached, csv, json, ledger_csv);
                }
                other => panic!("expected progress/result, got {other:?}"),
            }
        }
    }

    /// Daemon-wide simulated-realizations counter, via a status frame.
    fn sim_runs(&mut self, job: u64) -> u64 {
        self.send(&SessionFrame::Status { job });
        match self.recv() {
            SessionFrame::Report { sim_runs, .. } => sim_runs,
            other => panic!("expected report, got {other:?}"),
        }
    }
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dcd-serve-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn small_scenario() -> Scenario {
    let mut sc = find("paper-10-node").unwrap();
    sc.runs = 3;
    sc.iters = 400;
    sc.threads = 1;
    sc
}

/// Tentpole acceptance: a resubmit of the same (spec, seed) is served
/// from the cache byte-for-byte with **zero** additional simulation
/// work; perturbing the seed misses; a textually different but
/// semantically identical INI lands on the same entry.
#[test]
fn resubmit_hits_cache_byte_identical_with_zero_work() {
    let dir = tmp("resubmit");
    let daemon = spawn_daemon(&dir.join("cache"), &["--workers", "2"], &[]);
    let mut session = Session::open(&daemon.addr);
    let sc = small_scenario();
    let spec = sc.to_ini_string();

    let (job1, key1, cached1, csv1, json1, ledger1) = session.submit_and_wait(&spec);
    assert!(!cached1, "first submit must compute");
    let work_after_first = session.sim_runs(job1);
    assert_eq!(work_after_first, sc.runs as u64, "compute must bill its runs");

    // Resubmit: identical bytes, zero new work.
    let (job2, key2, cached2, csv2, json2, ledger2) = session.submit_and_wait(&spec);
    assert_ne!(job1, job2);
    assert_eq!(key1, key2);
    assert!(cached2, "resubmit must be a cache hit");
    assert_eq!(csv1, csv2, "cached CSV differs from computed CSV");
    assert_eq!(json1, json2, "cached JSON differs from computed JSON");
    assert_eq!(ledger1, ledger2, "cached ledger differs from computed ledger");
    assert_eq!(
        session.sim_runs(job2),
        work_after_first,
        "a cache hit must do zero simulation work"
    );

    // Seed perturbation: a different entry, computed fresh.
    let mut perturbed = small_scenario();
    perturbed.seed += 1;
    let (_, key3, cached3, csv3, ..) = session.submit_and_wait(&perturbed.to_ini_string());
    assert_ne!(key1, key3, "seed must be part of the cache key");
    assert!(!cached3);
    assert_ne!(csv1, csv3, "different seed, different trajectory");

    // A scrambled-but-equivalent INI (comments, blank lines, spacing,
    // explicit default-valued key) maps onto the SAME cache entry.
    let scrambled = format!(
        "; same scenario, different text\n\n[schedule]\nseed={}\nruns = {}\n  iters = {}\n\
         threads={}\nshards = {}\nrecord_every = {}\n\n[scenario]\n  name = {}\n\
         description = {}\n",
        sc.seed,
        sc.runs,
        sc.iters,
        sc.threads,
        sc.shards,
        sc.record_every,
        sc.name,
        sc.description,
    );
    let (_, key4, cached4, csv4, ..) = session.submit_and_wait(&scrambled);
    assert_eq!(key1, key4, "equivalent INI text must share the cache entry");
    assert!(cached4);
    assert_eq!(csv1, csv4);

    drop(session);
    daemon.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Single-key perturbation property: changing any one scenario INI key
/// moves the cache key (nothing is silently normalized away).
#[test]
fn perturbing_any_single_key_misses() {
    let sc = small_scenario();
    let base_key = job_key(&sc);
    for (dotted, value) in [
        ("scenario.name", "paper-10-node-b"),
        ("schedule.seed", "31"),
        ("schedule.runs", "4"),
        ("schedule.iters", "500"),
        ("schedule.threads", "2"),
        ("schedule.shards", "2"),
        ("algorithm.m", "2"),
        ("algorithm.m_grad", "2"),
        ("algorithm.mu", "0.02"),
        ("data.sigma_v2", "0.002"),
        ("impairments.drop_prob", "0.05"),
        // The dynamic axes (DESIGN.md §12): a bursty link process and
        // every `[dynamics]` knob must each perturb the key — a cached
        // static result must never answer a dynamic request.
        ("impairments.drop", "markov:0.1,0.3,0.4"),
        // The energy loop (DESIGN.md §13): splitting the shared erasure
        // into independent legs and pricing the radio each change the
        // simulated trajectory, so each must move the cache key — a
        // free-radio cached result must never answer a priced request.
        ("impairments.per_leg", "true"),
        ("energy.tx_j_per_bit", "5e-8"),
        ("energy.rx_j_per_bit", "2e-8"),
        ("dynamics.leave", "0.01"),
        ("dynamics.join", "0.5"),
        ("dynamics.require_connected", "true"),
        ("dynamics.rewire_period", "70"),
        ("dynamics.drift", "walk:0.001"),
        ("dynamics.adaptive", "metropolis"),
    ] {
        let mut doc = IniDoc::parse(&sc.to_ini_string()).unwrap();
        Scenario::check_key(dotted).unwrap_or_else(|e| panic!("{dotted}: {e}"));
        doc.set_dotted(&format!("{dotted}={value}")).unwrap();
        let perturbed = Scenario::from_ini(&doc).unwrap_or_else(|e| panic!("{dotted}: {e}"));
        assert_ne!(
            base_key,
            job_key(&perturbed),
            "perturbing {dotted} must change the cache key"
        );
    }
}

/// The one deliberate exception to the perturbation property:
/// `[schedule] lanes` is a pure throughput knob — the lane engine is
/// byte-identical at every width (DESIGN.md §14) — so a `lanes = 4`
/// submit must HIT the cache entry computed at the default width
/// instead of recomputing identical artifacts.
#[test]
fn lanes_is_artifact_neutral_in_the_cache_key() {
    let sc = small_scenario();
    let base_key = job_key(&sc);
    for value in ["4", "auto"] {
        let mut doc = IniDoc::parse(&sc.to_ini_string()).unwrap();
        Scenario::check_key("schedule.lanes").unwrap();
        doc.set_dotted(&format!("schedule.lanes={value}")).unwrap();
        let perturbed = Scenario::from_ini(&doc).unwrap();
        assert_eq!(
            base_key,
            job_key(&perturbed),
            "lanes = {value} must not move the cache key"
        );
        // The canonical form the daemon stores and executes is
        // lanes-free, so cached specs stay byte-stable too.
        let canon = canonical_scenario(&perturbed).to_ini_string();
        assert!(!canon.contains("lanes"), "canonical spec leaked lanes:\n{canon}");
    }
}

/// Crash-injection under the daemon: a worker killed mid-job is
/// re-spawned and the final artifacts are byte-identical to an
/// uncrashed local run of the same spec.
#[test]
fn worker_crash_under_daemon_converges_to_uncrashed_bytes() {
    let dir = tmp("crash");
    std::fs::create_dir_all(&dir).unwrap();
    let marker = dir.join("crash_once.marker");
    // Uncrashed reference: a plain local run (no daemon, no crash env).
    let local = dir.join("local");
    let base = [
        "scenario", "run", "--name", "paper-10-node", "--runs", "4", "--iters", "300",
        "--threads", "1", "--shards", "2", "--quiet",
    ];
    let mut args: Vec<&str> = base.to_vec();
    let local_s = local.to_str().unwrap().to_string();
    args.extend_from_slice(&["--out", &local_s]);
    let out = Command::new(binary())
        .args(&args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("local scenario run");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Daemon with the crash hook armed: its first spawned shard worker
    // exits mid-job, the supervisor re-spawns deterministically.
    let daemon = spawn_daemon(
        &dir.join("cache"),
        &["--workers", "1"],
        &[(dcd_lms::shard::CRASH_ONCE_ENV, marker.to_str().unwrap())],
    );
    let via = dir.join("via");
    let via_s = via.to_str().unwrap().to_string();
    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--out", &via_s, "--via", &daemon.addr]);
    let out = Command::new(binary())
        .args(&args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("scenario run --via");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "{text}");
    assert!(text.contains("cache miss"), "{text}");
    assert!(marker.exists(), "the crash hook should have fired in the daemon's worker");
    for artifact in ["paper-10-node.csv", "paper-10-node.json", "paper-10-node_ledger.csv"] {
        let l = std::fs::read_to_string(local.join(artifact)).unwrap();
        let v = std::fs::read_to_string(via.join(artifact)).unwrap();
        assert_eq!(l, v, "{artifact}: post-crash daemon bytes diverged from uncrashed local run");
    }
    daemon.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Client disconnect mid-stream: the job keeps running, the result
/// lands in the cache, and a later resubmit is a zero-work hit.
#[test]
fn client_disconnect_mid_stream_still_caches_the_result() {
    let dir = tmp("disconnect");
    let daemon = spawn_daemon(&dir.join("cache"), &["--workers", "1"], &[]);
    let mut sc = small_scenario();
    sc.runs = 6;
    sc.iters = 1500;
    let spec = sc.to_ini_string();

    // Submit, read only the accepted frame, then vanish.
    {
        let mut session = Session::open(&daemon.addr);
        session.send(&SessionFrame::Submit { spec: spec.clone(), wait: true });
        match session.recv() {
            SessionFrame::Accepted { cached, .. } => assert!(!cached),
            other => panic!("expected accepted, got {other:?}"),
        }
        // Dropping the session closes the socket mid-stream.
    }

    // A fresh session resubmits: it must get the finished result (the
    // queue owns the job; the dead client never cancelled it) and the
    // daemon must have simulated the realizations exactly once.
    let mut session = Session::open(&daemon.addr);
    let (job, _, cached, csv, ..) = session.submit_and_wait(&spec);
    assert!(cached, "orphaned job's result must land in the cache");
    assert!(!csv.is_empty());
    assert_eq!(
        session.sim_runs(job),
        sc.runs as u64,
        "the orphaned job must have computed exactly once"
    );
    drop(session);
    daemon.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
