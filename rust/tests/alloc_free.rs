//! Hot-path allocation discipline (EXPERIMENTS.md §Perf): the theory
//! engine's iteration loops must perform **zero heap allocations per
//! iteration**. Verified with a counting global allocator: a longer run
//! must allocate exactly as much as a shorter one (all allocations are
//! per-call setup — ping-pong Σ buffers, workspace, output vector).
//!
//! This file deliberately contains a single test: the allocator counter
//! is process-global and must not see traffic from concurrently running
//! tests.

use dcd_lms::algorithms::{Algorithm, CommMeter, Dcd, DiffusionLms, NetworkConfig};
use dcd_lms::coordinator::dynamics::{DynamicsConfig, DynamicsState};
use dcd_lms::coordinator::impairments::{AdaptivePolicy, DropModel, Gating, ImpairmentState, LinkImpairments};
use dcd_lms::coordinator::lanes::run_lane_block;
use dcd_lms::coordinator::runner::SchedulerOptions;
use dcd_lms::datamodel::DataModel;
use dcd_lms::theory::{ImpairedMsdModel, MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Graph, Rule};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAllocator;

// SAFETY: delegates directly to `System`; only adds a relaxed counter.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOCS.load(Ordering::Relaxed);
    let result = f();
    (ALLOCS.load(Ordering::Relaxed) - before, result)
}

#[test]
fn theory_iteration_loops_do_not_allocate() {
    // Sanity: the counter must actually observe heap traffic.
    let (sanity, _) = allocs_during(|| std::hint::black_box(Vec::<u8>::with_capacity(64)));
    assert!(sanity > 0, "counting allocator is not active");

    let n = 6;
    let l = 4;
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
    let setup = TheorySetup {
        n_nodes: n,
        dim: l,
        m: 2,
        m_grad: 1,
        c,
        mu: vec![5e-3; n],
        sigma_u2: (0..n).map(|k| 0.8 + 0.1 * k as f64).collect(),
        sigma_v2: vec![1e-3; n],
    };
    let model = MsdModel::new(setup);
    let wo = vec![0.4, -0.2, 0.7, 0.1];

    // Warm-up (page-in code paths, lazy runtime bits).
    let _ = model.trajectory(&wo, 8);
    let _ = model.steady_state(&wo, -1.0, 8);
    let _ = model.ms_stability_radius(8);

    // Per-call setup allocations (Σ ping-pong buffers, workspace, the
    // preallocated output vector) are identical for any iteration count,
    // so equal totals <=> zero allocations per iteration.
    let (short, _) = allocs_during(|| std::hint::black_box(model.trajectory(&wo, 100)));
    let (long, _) = allocs_during(|| std::hint::black_box(model.trajectory(&wo, 400)));
    assert_eq!(short, long, "trajectory allocates per iteration");

    // tol < 0 forces the loop to use the full iteration budget.
    let (short, _) = allocs_during(|| std::hint::black_box(model.steady_state(&wo, -1.0, 100)));
    let (long, _) = allocs_during(|| std::hint::black_box(model.steady_state(&wo, -1.0, 400)));
    assert_eq!(short, long, "steady_state allocates per iteration");

    let (short, _) = allocs_during(|| std::hint::black_box(model.ms_stability_radius(100)));
    let (long, _) = allocs_during(|| std::hint::black_box(model.ms_stability_radius(400)));
    assert_eq!(short, long, "ms_stability_radius allocates per iteration");

    // The impaired-link operator (DESIGN.md §7) rides the same engine
    // and must keep the same discipline: zero allocations per iteration
    // with drops, gating and the quantization noise floor all active.
    let setup = model.setup().clone();
    let imp = LinkImpairments {
        drop: DropModel::Iid(0.2),
        gating: Gating::Probabilistic(0.8),
        quant_step: 1e-3,
        per_leg: false,
    };
    let impaired = ImpairedMsdModel::new(setup, &imp).expect("bernoulli gating is in scope");
    let _ = impaired.trajectory(&wo, 8);
    let _ = impaired.steady_state(&wo, -1.0, 8);
    let _ = impaired.ms_stability_radius(8);

    let (short, _) = allocs_during(|| std::hint::black_box(impaired.trajectory(&wo, 100)));
    let (long, _) = allocs_during(|| std::hint::black_box(impaired.trajectory(&wo, 400)));
    assert_eq!(short, long, "impaired trajectory allocates per iteration");

    let (short, _) =
        allocs_during(|| std::hint::black_box(impaired.steady_state(&wo, -1.0, 100)));
    let (long, _) =
        allocs_during(|| std::hint::black_box(impaired.steady_state(&wo, -1.0, 400)));
    assert_eq!(short, long, "impaired steady_state allocates per iteration");

    let (short, _) = allocs_during(|| std::hint::black_box(impaired.ms_stability_radius(100)));
    let (long, _) = allocs_during(|| std::hint::black_box(impaired.ms_stability_radius(400)));
    assert_eq!(short, long, "impaired ms_stability_radius allocates per iteration");

    // The coordinator's per-iteration effective-matrix rebuild
    // (DESIGN.md §10) is one O(E) value memcpy plus in-place CSR edits —
    // it must also run without heap traffic once the state exists.
    let graph = Graph::random_geometric(12, 0.5, &mut dcd_lms::rng::Pcg64::new(8, 0));
    let n = graph.n();
    let a = combination_matrix(&graph, Rule::Metropolis);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let net = NetworkConfig { graph, c, a, mu: vec![5e-3; n], dim: 4 };
    let mut alg = Dcd::new(net.clone(), 2, 1);
    let mut comm = CommMeter::new(n);
    let mut state = ImpairmentState::new(&net, 77, 1);
    let rebuild = |state: &mut ImpairmentState,
                   alg: &mut Dcd,
                   comm: &mut CommMeter,
                   iters: usize| {
        for _ in 0..iters {
            state.begin_iteration(&imp, alg, comm);
        }
    };
    rebuild(&mut state, &mut alg, &mut comm, 8); // warm-up
    let (short, _) = allocs_during(|| rebuild(&mut state, &mut alg, &mut comm, 100));
    let (long, _) = allocs_during(|| rebuild(&mut state, &mut alg, &mut comm, 400));
    assert_eq!(short, long, "impairment rebuild allocates per iteration");

    // Same discipline for the expected-combiner (Ā, C̄) refresh used by
    // the theory anchor: the `_into` variants reuse caller buffers.
    let mut a_bar = net.a.clone();
    let mut c_bar = net.c.clone();
    imp.expected_combiners_into(&net, &mut a_bar, &mut c_bar)
        .expect("bernoulli gating has expected combiners");
    let refresh = |a_bar: &mut dcd_lms::topology::Combiner,
                   c_bar: &mut dcd_lms::topology::Combiner,
                   iters: usize| {
        for _ in 0..iters {
            let _ = imp.expected_combiners_into(&net, a_bar, c_bar);
        }
    };
    let (short, _) = allocs_during(|| refresh(&mut a_bar, &mut c_bar, 50));
    let (long, _) = allocs_during(|| refresh(&mut a_bar, &mut c_bar, 200));
    assert_eq!(short, long, "expected_combiners_into allocates per call");

    // The dynamic axes (DESIGN.md §12) keep the same discipline: the
    // Gilbert–Elliott chain state, the occupancy histogram, and the
    // churn/mobility/adaptive layer are all allocated once per run.
    let bursty = LinkImpairments {
        drop: DropModel::Markov { p_bad: 0.3, p_gb: 0.2, p_bg: 0.2 },
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    let dc = DynamicsConfig {
        leave: 0.01,
        join: 0.2,
        require_connected: true,
        adaptive: AdaptivePolicy::Metropolis,
        ..DynamicsConfig::default()
    };
    let mut state = ImpairmentState::new(&net, 78, 1);
    let mut ds = DynamicsState::new(dc, &net, 78, 1);
    let dyn_rebuild = |state: &mut ImpairmentState,
                       ds: &mut DynamicsState,
                       alg: &mut Dcd,
                       comm: &mut CommMeter,
                       iters: usize| {
        for _ in 0..iters {
            state.begin_iteration_dynamic(&bursty, Some(&mut *ds), alg, comm);
        }
    };
    // Warm-up covers the lazy stationary seeding, the first burst
    // tallies, and at least one adaptive refresh (period 64).
    dyn_rebuild(&mut state, &mut ds, &mut alg, &mut comm, 128);
    let (short, _) = allocs_during(|| dyn_rebuild(&mut state, &mut ds, &mut alg, &mut comm, 200));
    let (long, _) = allocs_during(|| dyn_rebuild(&mut state, &mut ds, &mut alg, &mut comm, 800));
    assert_eq!(short, long, "dynamic rebuild allocates per iteration");

    // The lane engine's batched inner loop (DESIGN.md §14) keeps the
    // same discipline: SoA state, per-lane RNGs/meters and the
    // lane-blocked effective combiners are allocated once per block, so
    // a longer block allocates exactly as much as a shorter one — the
    // per-node `to_vec` of the scalar step is exactly what the batched
    // path amortises away. Checked ideal and impaired (drops + gating +
    // quantization rebuild every iteration).
    let model = DataModel::paper(n, 4, 0.8, 1.2, 1e-3, &mut dcd_lms::rng::Pcg64::new(5, 0));
    let lane_allocs = |make: &dyn Fn() -> Box<dyn Algorithm>,
                       opts: &SchedulerOptions,
                       iters: usize| {
        let mut alg = make();
        let (count, res) = allocs_during(|| {
            std::hint::black_box(run_lane_block(&model, opts, alg.as_mut(), iters, 91, 4, 0, 4))
        });
        assert_eq!(res.len(), 4);
        count
    };
    let ideal_opts = SchedulerOptions::default();
    let impaired_opts = SchedulerOptions::from_impairments(Some(&imp));
    let lms: &dyn Fn() -> Box<dyn Algorithm> = &|| Box::new(DiffusionLms::new(net.clone()));
    let dcd: &dyn Fn() -> Box<dyn Algorithm> = &|| Box::new(Dcd::new(net.clone(), 2, 1));
    for (label, make) in [("diffusion-lms", lms), ("dcd", dcd)] {
        for (kind, opts) in [("ideal", &ideal_opts), ("impaired", &impaired_opts)] {
            let _ = lane_allocs(make, opts, 8); // warm-up
            let short = lane_allocs(make, opts, 100);
            let long = lane_allocs(make, opts, 400);
            assert_eq!(
                short, long,
                "{label} ({kind}): the batched inner loop allocates per iteration"
            );
        }
    }
}
