//! Scenario-subsystem acceptance tests: thread-count bit-identity for
//! impaired runs, and exact equivalence between the `paper-10-node`
//! scenario and the Experiment 1 driver on ideal links.

use dcd_lms::config::Exp1Config;
use dcd_lms::experiments::{run_exp1, Engine};
use dcd_lms::scenario::{self, Scenario};

/// `scenario run --name lossy-geometric --seed 7` must be bit-identical
/// at 1, 2 and 4 worker threads (the acceptance criterion; shrunk
/// workload, same code path).
#[test]
fn lossy_geometric_bit_identical_across_thread_counts() {
    let mut sc = scenario::find("lossy-geometric").expect("registry has lossy-geometric");
    sc.seed = 7;
    sc.runs = 6;
    sc.iters = 500;
    sc.record_every = 1;
    sc.threads = 1;
    let reference = scenario::run_scenario(&sc, None, true).unwrap();
    for threads in [2usize, 4] {
        let mut sct = sc.clone();
        sct.threads = threads;
        let out = scenario::run_scenario(&sct, None, true).unwrap();
        assert_eq!(out.series[0].y, reference.series[0].y, "threads = {threads}");
        assert_eq!(
            out.steady_db.to_bits(),
            reference.steady_db.to_bits(),
            "threads = {threads}"
        );
        assert_eq!(
            out.scalars_per_run.to_bits(),
            reference.scalars_per_run.to_bits()
        );
    }
}

/// With ideal links (drop probability 0, no gating, no quantization) the
/// `paper-10-node` scenario reproduces the exp1 DCD simulation
/// trajectory exactly — same topology, model stream, Monte-Carlo seeds
/// and recording grid.
#[test]
fn paper_scenario_matches_exp1_trajectory_exactly() {
    let cfg = Exp1Config { runs: 4, iters: 2_000, ..Exp1Config::default() };
    let exp1 = run_exp1(&cfg, Engine::Rust, None, true).unwrap();
    let dcd_sim = exp1
        .series
        .iter()
        .find(|s| s.label == "dcd (sim)")
        .expect("exp1 emits a dcd (sim) series");

    let mut sc: Scenario = scenario::find("paper-10-node").unwrap();
    assert!(sc.impairments.is_ideal());
    sc.runs = cfg.runs;
    sc.iters = cfg.iters;
    sc.record_every = 0; // auto — the exp1 convention
    let out = scenario::run_scenario(&sc, None, true).unwrap();

    assert_eq!(out.series[0].x, dcd_sim.x);
    assert_eq!(out.series[0].y, dcd_sim.y, "scenario and exp1 trajectories diverge");
}

/// The scenario INI written by `to_ini_string` is a valid `--config`
/// input that reproduces the same run (CLI contract).
#[test]
fn serialized_scenario_reruns_identically() {
    let mut sc = scenario::find("quantized-dense").unwrap();
    sc.runs = 3;
    sc.iters = 300;
    sc.record_every = 1;
    let direct = scenario::run_scenario(&sc, None, true).unwrap();
    let reparsed = Scenario::parse_str(&sc.to_ini_string()).unwrap();
    let again = scenario::run_scenario(&reparsed, None, true).unwrap();
    assert_eq!(direct.series[0].y, again.series[0].y);
}
