//! End-to-end CLI smoke: run the compiled binary the way a user would.

use std::path::PathBuf;
use std::process::Command;

fn binary() -> PathBuf {
    // target/<profile>/dcd-lms next to the test executable.
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release|debug
    p.push("dcd-lms");
    p
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(binary())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn dcd-lms");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["--help"]);
    assert!(ok);
    for cmd in ["exp1", "exp2", "exp3", "scenario", "theory", "validate", "info"] {
        assert!(text.contains(cmd), "help missing {cmd}:\n{text}");
    }
}

#[test]
fn unknown_command_fails_gracefully() {
    let (_ok, text) = run(&["frobnicate"]);
    assert!(text.contains("unknown command"));
}

#[test]
fn info_prints_manifest() {
    let (ok, text) = run(&["info"]);
    assert!(ok, "{text}");
    // With artifacts built, info lists the modules; otherwise it says so.
    assert!(
        text.contains("dcd_smoke") || text.contains("artifacts: unavailable"),
        "{text}"
    );
    assert!(text.contains("connected: true"), "{text}");
}

#[test]
fn theory_reports_stability() {
    let (ok, text) = run(&["theory", "--mu", "0.005", "--iters", "4000"]);
    assert!(ok, "{text}");
    assert!(text.contains("mean-stable: true"), "{text}");
    assert!(text.contains("steady-state MSD"), "{text}");
}

#[test]
fn validate_reports_agreement() {
    let (ok, text) = run(&["validate"]);
    assert!(ok, "{text}");
    // Full agreement check when the PJRT runtime is linked in; an
    // explicit skip notice under the offline `xla` stub.
    assert!(
        text.contains("engines agree") || text.contains("validate skipped"),
        "{text}"
    );
}

#[test]
fn exp1_fast_writes_results() {
    let dir = std::env::temp_dir().join("dcd_cli_e2e_exp1");
    std::fs::remove_dir_all(&dir).ok();
    let out = dir.to_str().unwrap();
    let (ok, text) = run(&[
        "exp1", "--fast", "--runs", "4", "--iters", "2000", "--out", out, "--quiet",
    ]);
    assert!(ok, "{text}");
    assert!(dir.join("exp1_fig3_left.csv").exists());
    assert!(dir.join("exp1_fig3_left.json").exists());
    let csv = std::fs::read_to_string(dir.join("exp1_fig3_left.csv")).unwrap();
    assert!(csv.lines().next().unwrap().contains("dcd (theory)"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_list_shows_the_registry() {
    let (ok, text) = run(&["scenario", "list"]);
    assert!(ok, "{text}");
    for name in [
        "paper-10-node",
        "fifty-node-sweep",
        "wsn-80",
        "lossy-geometric",
        "event-triggered-ring",
        "quantized-dense",
        "mega-grid",
    ] {
        assert!(text.contains(name), "scenario list missing {name}:\n{text}");
    }
}

#[test]
fn scenario_run_writes_results_thread_independent() {
    let dir = std::env::temp_dir().join("dcd_cli_e2e_scenario");
    std::fs::remove_dir_all(&dir).ok();
    let run_with_threads = |threads: &str, sub: &str| {
        let out = dir.join(sub);
        let out_s = out.to_str().unwrap().to_string();
        let (ok, text) = run(&[
            "scenario", "run", "--name", "lossy-geometric", "--seed", "7", "--fast",
            "--threads", threads, "--out", &out_s, "--quiet",
        ]);
        assert!(ok, "{text}");
        std::fs::read_to_string(out.join("lossy-geometric.csv")).unwrap()
    };
    let csv1 = run_with_threads("1", "t1");
    let csv4 = run_with_threads("4", "t4");
    assert_eq!(csv1, csv4, "scenario run is not thread-count invariant");
    assert!(dir.join("t1/lossy-geometric.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_sweep_writes_summary() {
    let dir = std::env::temp_dir().join("dcd_cli_e2e_sweep");
    std::fs::remove_dir_all(&dir).ok();
    let out_s = dir.to_str().unwrap().to_string();
    let (ok, text) = run(&[
        "scenario", "sweep", "--name", "lossy-geometric", "--fast", "--quiet",
        "--key", "impairments.drop_prob", "--values", "0,0.3", "--out", &out_s,
    ]);
    assert!(ok, "{text}");
    assert!(dir.join("lossy-geometric_sweep.csv").exists());
    assert!(dir.join("lossy-geometric_sweep.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_rejects_unknown_name_and_action() {
    let (ok, text) = run(&["scenario", "run", "--name", "no-such-thing"]);
    assert!(!ok);
    assert!(text.contains("unknown scenario"), "{text}");
    let (ok, text) = run(&["scenario", "frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown scenario action"), "{text}");
}

#[test]
fn config_overrides_apply() {
    let dir = std::env::temp_dir().join("dcd_cli_e2e_cfg");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("cfg.ini");
    std::fs::write(&cfg_path, "[exp1]\nruns = 2\niters = 500\nmu = 0.01\n").unwrap();
    let out = dir.to_str().unwrap();
    let (ok, text) = run(&[
        "exp1",
        "--config",
        cfg_path.to_str().unwrap(),
        "--set",
        "exp1.iters=800",
        "--out",
        out,
        "--quiet",
    ]);
    assert!(ok, "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
