//! Protocol fuzz battery (DESIGN.md §8 frame grammar + §11 session
//! grammar): truncated frames, unknown versions, NaN/Inf floats,
//! counters past 2^53, deep nesting and interleaved garbage are thrown
//! at both the shard v2 parser and the serve v3 session parser — on
//! the decode API, on a live worker's stdin, on the supervisor's
//! worker pipe, and on a live stdio serve session. The contract is
//! uniform: a contextual error naming the frame index and the
//! offending field, **never** a panic, and (for sessions) the session
//! survives the bad frame.

use std::io::Write;
use std::panic::catch_unwind;
use std::path::PathBuf;
use std::process::{Command, Stdio};

use dcd_lms::scenario::find;
use dcd_lms::serve::SessionFrame;
use dcd_lms::shard::{Frame, JobKind, ShardJob};

fn binary() -> PathBuf {
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release|debug
    p.push("dcd-lms");
    p
}

/// A valid v2 job frame line to mutate.
fn valid_job_line() -> String {
    let mut sc = find("paper-10-node").unwrap();
    sc.runs = 2;
    sc.iters = 100;
    Frame::Job(ShardJob {
        kind: JobKind::Mc,
        payload: sc.to_ini_string(),
        run_start: 0,
        run_count: 2,
        threads: 1,
        algo_index: 0,
    })
    .encode()
}

/// A valid v3 submit frame line to mutate.
fn valid_submit_line() -> String {
    let mut sc = find("paper-10-node").unwrap();
    sc.runs = 2;
    sc.iters = 100;
    SessionFrame::Submit { spec: sc.to_ini_string(), wait: true }.encode()
}

/// A valid v2 WSN result frame (with a priced-radio block) to mutate.
fn valid_wsn_run_line() -> String {
    Frame::Run {
        run: 0,
        payload: dcd_lms::shard::RunPayload::Wsn(dcd_lms::coordinator::WsnResult {
            time: vec![500.0, 1000.0],
            msd: vec![0.5, 0.25],
            mean_sleep: vec![10.0, 12.0],
            mean_harvest: vec![0.01, 0.02],
            activations: 5,
            skipped: 1,
            gated: 2,
            per_node_activations: vec![2, 2, 1],
            radio_joules: vec![1.25e-3, 0.0, 7.5e-4],
            ledger: dcd_lms::energy::CommLedger::empty(3),
        }),
    }
    .encode()
}

/// Every mutation of both grammars' lines must produce `Err`, never a
/// panic — the decode APIs are total functions over arbitrary bytes.
#[test]
fn truncations_and_mutations_never_panic_either_parser() {
    let seeds = [valid_job_line(), valid_submit_line(), valid_wsn_run_line()];
    let mut cases: Vec<String> = Vec::new();
    for line in &seeds {
        // Every prefix truncation (byte-safe: char boundaries only).
        for (i, _) in line.char_indices() {
            cases.push(line[..i].to_string());
        }
        // Single-byte corruptions at a stride, plus structural stabs.
        let bytes = line.as_bytes();
        for pos in (0..bytes.len()).step_by(7) {
            let mut b = bytes.to_vec();
            b[pos] = b[pos].wrapping_add(13);
            cases.push(String::from_utf8_lossy(&b).into_owned());
        }
        cases.push(format!("{line}{line}"));
        cases.push(line.replace(':', ","));
        cases.push(line.replace('{', "["));
    }
    for garbage in [
        "",
        "   ",
        "null",
        "42",
        "\"a string, not an object\"",
        "{}",
        "[]",
        "{\"v\":}",
        "{\"v\":2",
        "not json at all \u{1f980}",
        "{\"v\":2,\"type\":\"job\",\"payload\":123}",
        "{\"v\":3,\"type\":\"submit\",\"spec\":123}",
    ] {
        cases.push(garbage.to_string());
    }
    // Deep nesting must be a catchable error, not a stack overflow.
    cases.push("[".repeat(100_000));
    cases.push(format!("{}1{}", "{\"v\":".repeat(50_000), "}".repeat(50_000)));
    for case in &cases {
        let v2 = case.clone();
        let out = catch_unwind(move || Frame::decode(&v2).map(|_| ()));
        let decoded = out.unwrap_or_else(|_| panic!("v2 decode panicked on {case:?}"));
        if case == &seeds[0] {
            assert!(decoded.is_ok());
        }
        let v3 = case.clone();
        let out = catch_unwind(move || SessionFrame::decode(&v3).map(|_| ()));
        let decoded = out.unwrap_or_else(|_| panic!("v3 decode panicked on {case:?}"));
        if case == &seeds[1] {
            assert!(decoded.is_ok());
        }
        if case == &seeds[2] {
            let v2 = case.clone();
            assert!(Frame::decode(&v2).is_ok(), "pristine wsn run frame must decode");
        }
    }
}

/// The radio block of a WSN result frame (DESIGN.md §13): a malformed
/// `radio_joules` is a contextual error naming the field, never a
/// panic; the non-finite string spellings `num_f64` emits survive, and
/// a string holding a *finite* number is refused (only values
/// `Json::Num` cannot carry may ride in a string).
#[test]
fn malformed_radio_blocks_are_field_named_errors() {
    let frame_with = |radio: &str| {
        format!(
            "{{\"v\":2,\"type\":\"run\",\"kind\":\"wsn\",\"run\":0,\
             \"time\":[500.0],\"msd\":[0.5],\"mean_sleep\":[10.0],\
             \"mean_harvest\":[0.01],\"activations\":1,\"skipped\":0,\
             \"gated\":0,\"per_node_activations\":[1,0,0],\
             \"radio_joules\":{radio},\
             \"ledger\":{{\"n\":3,\"scalars\":0,\"messages\":0,\"suppressed\":0,\
             \"dropped_s\":0,\"dropped_m\":0,\"width\":64,\"per_node\":[0,0,0],\
             \"per_purpose\":[0,0,0],\"per_link\":[]}}}}"
        )
    };
    for bad in ["\"bogus\"", "{}", "42", "[0.001,\"bogus\"]", "[true]", "[\"0.5\"]", "[[1.0]]"] {
        let line = frame_with(bad);
        let out = catch_unwind(move || Frame::decode(&frame_with(bad)).map(|_| ()));
        let err = out
            .unwrap_or_else(|_| panic!("decode panicked on radio block {bad}"))
            .expect_err(&line);
        assert!(err.contains("radio_joules"), "radio block {bad}: {err}");
    }
    // A diverged node's non-finite bill survives the pipe bit-for-bit.
    match Frame::decode(&frame_with("[\"inf\",\"NaN\",0.0]")).unwrap() {
        Frame::Run { payload: dcd_lms::shard::RunPayload::Wsn(back), .. } => {
            assert_eq!(back.radio_joules[0], f64::INFINITY);
            assert!(back.radio_joules[1].is_nan());
            assert_eq!(back.radio_joules[2], 0.0);
        }
        other => panic!("decoded {other:?}"),
    }
}

/// Version skew is named, in both directions: the worker-pipe parser
/// rejects v1/v3/v99, the session parser rejects v2/v4.
#[test]
fn unknown_versions_are_named() {
    for v in [0, 1, 3, 4, 99] {
        let err = Frame::decode(&format!("{{\"v\":{v},\"type\":\"done\",\"runs\":0}}"))
            .unwrap_err();
        assert!(err.contains(&format!("version {v}")), "{err}");
    }
    for v in [0, 1, 2, 4, 99] {
        let err = SessionFrame::decode(&format!("{{\"v\":{v},\"type\":\"bye\"}}")).unwrap_err();
        assert!(err.contains(&format!("version {v}")), "{err}");
    }
}

/// Floats that don't survive JSON (NaN, Inf) and counters past 2^53
/// are contextual errors naming the offending field, on both parsers.
#[test]
fn nan_inf_and_oversized_counters_are_contextual_errors() {
    // Bare NaN / Infinity tokens are not JSON; the parse layer rejects
    // them before any field logic.
    for token in ["NaN", "Infinity", "-Infinity"] {
        let line = format!("{{\"v\":2,\"type\":\"run\",\"run\":0,\"msd\":[{token}]}}");
        let err = Frame::decode(&line).unwrap_err();
        assert!(err.contains("shard protocol"), "{err}");
        let line = format!("{{\"v\":3,\"type\":\"progress\",\"job\":{token}}}");
        let err = SessionFrame::decode(&line).unwrap_err();
        assert!(err.contains("session protocol"), "{err}");
    }
    // 2^53 + 2: representable as f64 only by rounding, so the exact-u64
    // accessor refuses rather than silently folding counters.
    let big = (1u64 << 53) + 2;
    let line = format!(
        "{{\"v\":2,\"type\":\"job\",\"kind\":\"mc\",\"payload\":\"\",\"run_start\":{big},\
         \"run_count\":1,\"threads\":1,\"algo_index\":0}}"
    );
    let err = Frame::decode(&line).unwrap_err();
    assert!(err.contains("run_start"), "{err}");
    let line = format!("{{\"v\":3,\"type\":\"status\",\"job\":{big}}}");
    let err = SessionFrame::decode(&line).unwrap_err();
    assert!(err.contains("job"), "{err}");
    // The largest exact integer is still accepted.
    let ok = format!("{{\"v\":3,\"type\":\"status\",\"job\":{}}}", 1u64 << 53);
    assert!(SessionFrame::decode(&ok).is_ok());
}

fn run_worker_with_stdin(input: &str) -> (bool, String) {
    let mut child = Command::new(binary())
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn shard-worker");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(input.as_bytes())
        .expect("write to shard-worker");
    let out = child.wait_with_output().expect("wait for shard-worker");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// A live worker fed fuzz on stdin dies with a contextual diagnostic —
/// exit code, not signal; message, not stack trace.
#[test]
fn live_worker_survives_fuzz_with_clean_errors() {
    // Structurally valid job frames whose INI payload carries a
    // malformed energy-loop key (DESIGN.md §13): the worker must die
    // naming the key, not panic mid-simulation.
    let bad_payload_job = |payload: &str| {
        format!(
            "{}\n",
            Frame::Job(ShardJob {
                // `Mc` is the scenario-replay kind; a `mode = wsn`
                // scenario still enters through it (`Wsn` is exp3).
                kind: JobKind::Mc,
                payload: payload.to_string(),
                run_start: 0,
                run_count: 1,
                threads: 1,
                algo_index: 0,
            })
            .encode()
        )
    };
    let bad_tx = bad_payload_job(
        "[scenario]\nname = fuzz-energy\n\n[energy]\ntx_j_per_bit = banana\n\
         \n[schedule]\nmode = wsn\n",
    );
    let bad_leg = bad_payload_job(
        "[scenario]\nname = fuzz-leg\n\n[impairments]\nper_leg = maybe\n",
    );
    for (input, needle) in [
        ("\u{0}\u{0}\u{0}garbage\n", "shard protocol"),
        ("{\"v\":3,\"type\":\"submit\",\"spec\":\"\"}\n", "version 3"),
        ("{\"v\":2,\"type\":\"run\",\"run\":0,\"msd\":[]}\n", "expected a job frame"),
        (
            "{\"v\":2,\"type\":\"job\",\"kind\":\"mc\",\"payload\":\"\",\
             \"run_start\":9007199254740994,\"run_count\":1,\"threads\":1,\"algo_index\":0}\n",
            "run_start",
        ),
        (bad_tx.as_str(), "energy.tx_j_per_bit"),
        (bad_leg.as_str(), "impairments.per_leg"),
    ] {
        let (ok, text) = run_worker_with_stdin(input);
        assert!(!ok, "worker accepted fuzz {input:?}: {text}");
        assert!(text.contains(needle), "fuzz {input:?}: wanted {needle:?} in: {text}");
    }
}

/// Supervisor side: an impostor worker answering the v2 pipe with
/// interleaved garbage is diagnosed by frame index — never folded into
/// results, never a hang (satellite: both sides of the v2 pipe).
#[cfg(unix)]
#[test]
fn supervisor_diagnoses_interleaved_garbage_by_frame_index() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join(format!("dcd-fuzz-impostor-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // One plausible-but-wrong line, then garbage: the supervisor must
    // point at frame 1 (the first worker line it cannot use).
    let script = dir.join("impostor.sh");
    std::fs::write(
        &script,
        "#!/bin/sh\nread _job\necho '{\"v\":2,\"type\":\"nonsense\"}'\necho 'interleaved garbage'\n",
    )
    .unwrap();
    std::fs::set_permissions(&script, std::fs::Permissions::from_mode(0o755)).unwrap();
    let out = Command::new(binary())
        .args([
            "scenario", "run", "--name", "paper-10-node", "--runs", "2", "--iters", "100",
            "--shards", "2", "--quiet",
        ])
        .env(dcd_lms::shard::WORKER_BIN_ENV, script.to_str().unwrap())
        .env(dcd_lms::shard::RETRIES_ENV, "0")
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn dcd-lms");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(!out.status.success(), "impostor must fail the run: {text}");
    assert!(text.contains("worker frame 1 malformed"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A live stdio serve session under fuzz: every bad line is answered
/// with an `error` frame carrying the 1-based frame index, the session
/// keeps serving (a valid submit after the garbage still completes),
/// and EOF exits cleanly.
#[test]
fn serve_session_survives_fuzz_and_reports_frame_indices() {
    let dir = std::env::temp_dir().join(format!("dcd-fuzz-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = dir.join("cache");
    let mut sc = find("paper-10-node").unwrap();
    sc.runs = 2;
    sc.iters = 200;
    sc.threads = 1;
    let mut input = String::new();
    input.push_str("complete garbage\n"); // frame 1
    input.push_str("{\"v\":9,\"type\":\"submit\"}\n"); // frame 2: bad version
    input.push_str("{\"v\":3,\"type\":\"status\",\"job\":777}\n"); // frame 3: unknown job
    input.push_str("{\"v\":3,\"type\":\"bye\"}\n"); // frame 4: wrong direction
    input.push_str("{\"v\":3,\"type\":\"submit\",\"spec\":\"[algorithm]\\nname = quantum\\n\"}\n"); // frame 5
    input.push_str(&format!("{}\n", SessionFrame::Submit { spec: sc.to_ini_string(), wait: true }.encode())); // frame 6
    input.push_str(&format!("{}\n", SessionFrame::Shutdown.encode())); // frame 7
    let mut child = Command::new(binary())
        .args(["serve", "--cache", cache.to_str().unwrap(), "--workers", "1"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn dcd-lms serve");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(input.as_bytes())
        .expect("write session input");
    let out = child.wait_with_output().expect("wait for serve");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "fuzzed session must still exit cleanly: {stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let frames: Vec<SessionFrame> = stdout
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| SessionFrame::decode(l).unwrap_or_else(|e| panic!("daemon emitted {e}: {l}")))
        .collect();
    // Frames 1, 2, 3, 4, 5 each draw an error naming their index.
    for want in 1..=5u64 {
        assert!(
            frames.iter().any(|f| matches!(f,
                SessionFrame::Error { frame, message } if *frame == want
                    && message.contains(&format!("frame {want}")))),
            "no error frame for input frame {want}: {stdout}"
        );
    }
    // The good submit after all that garbage still ran to completion.
    assert!(
        frames.iter().any(|f| matches!(f, SessionFrame::Accepted { .. })),
        "{stdout}"
    );
    assert!(
        frames
            .iter()
            .any(|f| matches!(f, SessionFrame::Result { cached: false, .. })),
        "{stdout}"
    );
    assert!(
        matches!(frames.last(), Some(SessionFrame::Bye)),
        "session must end with bye: {stdout}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Energy-loop submits that parse but violate the §13 validators — a
/// priced radio outside WSN mode, per-leg erasure outside rounds mode,
/// a negative per-bit cost — each draw a frame-indexed error naming the
/// broken rule, the session survives all three, and EOF is clean.
#[test]
fn invalid_energy_loop_submits_draw_frame_indexed_errors() {
    let dir = std::env::temp_dir().join(format!("dcd-fuzz-energy-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = dir.join("cache");
    let specs = [
        // frame 1: priced radio without the WSN charge state
        "[scenario]\nname = fuzz-e1\n\n[energy]\ntx_j_per_bit = 5e-8\n".to_string(),
        // frame 2: per-leg erasure on the event-driven engine
        "[scenario]\nname = fuzz-e2\n\n[impairments]\nper_leg = true\n\
         \n[schedule]\nmode = wsn\n"
            .to_string(),
        // frame 3: a radio that pays you to transmit
        "[scenario]\nname = fuzz-e3\n\n[energy]\ntx_j_per_bit = -1\n\
         \n[schedule]\nmode = wsn\n"
            .to_string(),
    ];
    let mut input = String::new();
    for spec in &specs {
        input.push_str(&format!(
            "{}\n",
            SessionFrame::Submit { spec: spec.clone(), wait: true }.encode()
        ));
    }
    input.push_str(&format!("{}\n", SessionFrame::Shutdown.encode())); // frame 4
    let mut child = Command::new(binary())
        .args(["serve", "--cache", cache.to_str().unwrap(), "--workers", "1"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .spawn()
        .expect("spawn dcd-lms serve");
    child
        .stdin
        .take()
        .unwrap()
        .write_all(input.as_bytes())
        .expect("write session input");
    let out = child.wait_with_output().expect("wait for serve");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(
        out.status.success(),
        "session must survive invalid submits: {stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let frames: Vec<SessionFrame> = stdout
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| SessionFrame::decode(l).unwrap_or_else(|e| panic!("daemon emitted {e}: {l}")))
        .collect();
    for (want, needle) in [
        (1u64, "schedule.mode = wsn"),
        (2, "schedule.mode = rounds"),
        (3, "tx_j_per_bit"),
    ] {
        assert!(
            frames.iter().any(|f| matches!(f,
                SessionFrame::Error { frame, message } if *frame == want
                    && message.contains(&format!("frame {want}"))
                    && message.contains(needle))),
            "no frame-{want} error naming {needle:?}: {stdout}"
        );
    }
    assert!(
        !frames.iter().any(|f| matches!(f, SessionFrame::Accepted { .. })),
        "an invalid energy-loop submit must never be accepted: {stdout}"
    );
    assert!(
        matches!(frames.last(), Some(SessionFrame::Bye)),
        "session must end with bye: {stdout}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
