//! Statistical validation of the dynamic-network axes (DESIGN.md §12):
//! the Gilbert–Elliott link chain is pinned against its closed forms —
//! stationary Bad occupancy, geometric burst-length law (chi-square),
//! mean burst length — and the churn layer's connectivity contract is
//! exercised under sustained leave/join pressure.
//!
//! Every test is seeded (deterministic), but the tolerances are sized
//! from the estimators' sampling distributions so the assertions would
//! catch a wrong chain, not a wrong seed:
//!
//! * Occupancy: the chain's samples are correlated with relaxation time
//!   τ ≈ 1/min(p_gb, p_bg), so the occupancy estimate over T sampled
//!   steps has std ≈ sqrt(2·π(1−π)·τ/T). The asserted absolute
//!   tolerances are ≥ 5 of those standard deviations.
//! * Burst chi-square: completed bursts are i.i.d. geometric(q) with
//!   q = p_bg·(1 − p_bad), so Pearson's statistic over the merged-tail
//!   histogram is χ²(dof); the critical value is the Wilson–Hilferty
//!   99.98% quantile (z = 3.5) — a wrong law blows past it by orders
//!   of magnitude at ~10⁵ bursts.
//! * Mean burst: relative tolerance 5% ≈ 15 std of the sample mean at
//!   the burst counts below.

use dcd_lms::algorithms::{CommMeter, Dcd, NetworkConfig};
use dcd_lms::coordinator::dynamics::{DynamicsConfig, DynamicsState};
use dcd_lms::coordinator::impairments::{
    DropModel, Gating, ImpairmentState, LinkImpairments, LinkStateStats,
};
use dcd_lms::rng::Pcg64;
use dcd_lms::scenario::{find, mc_parts, scheduler_options, theory_scope};
use dcd_lms::topology::{combination_matrix, Graph, Rule};

fn ring_net(n: usize, dim: usize) -> NetworkConfig {
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![5e-3; n], dim }
}

/// Drive the impairment layer for `iters` iterations with `drop` on a
/// 10-node ring (20 directed slots sampled per iteration) and return
/// the chain's occupancy counters.
fn chain_stats(drop: DropModel, iters: usize, seed: u64) -> LinkStateStats {
    let net = ring_net(10, 2);
    let imp = LinkImpairments { drop, gating: Gating::Always, quant_step: 0.0, per_leg: false };
    let mut alg = Dcd::new(net.clone(), 1, 1);
    let mut comm = CommMeter::new(net.n_nodes());
    let mut state = ImpairmentState::new(&net, seed, 1);
    for _ in 0..iters {
        state.begin_iteration(&imp, &mut alg, &mut comm);
    }
    state.into_stats()
}

/// Stationary Bad occupancy π_B = p_gb·p_bad / (p_gb·p_bad +
/// p_bg·(1 − p_bad)), hit by the empirical bad fraction across
/// symmetric, sticky and asymmetric parameterizations.
#[test]
fn stationary_occupancy_matches_closed_form() {
    let iters = 50_000; // 20 slots → 10⁶ sampled chain steps.
    for &(p_bad, p_gb, p_bg, seed) in &[
        (0.2, 0.25, 0.25, 101u64), // symmetric: π_B = p_bad exactly
        (0.1, 0.05, 0.40, 102),    // sticky Good state, τ = 20
        (0.5, 0.30, 0.10, 103),    // sticky Bad state
    ] {
        let drop = DropModel::Markov { p_bad, p_gb, p_bg };
        let pi = drop.mean_drop();
        if p_gb == p_bg {
            assert_eq!(pi, p_bad, "symmetric redraw must give π_B = p_bad");
        }
        let stats = chain_stats(drop, iters, seed);
        let total = stats.good_steps + stats.bad_steps;
        assert_eq!(total, 20 * iters as u64, "every slot sampled every iteration");
        let emp = stats.bad_fraction().expect("chain was sampled");
        // τ ≤ 20 here, so std ≤ sqrt(2·0.25·20/10⁶) ≈ 0.0032; 0.025
        // is ≈ 8 std for the stickiest case.
        assert!(
            (emp - pi).abs() < 0.025,
            "markov:{p_bad},{p_gb},{p_bg}: occupancy {emp:.4} vs π_B {pi:.4}"
        );
    }
}

/// Completed bad bursts are geometric: P(len = j) = q·(1−q)^(j−1) with
/// q = p_bg·(1 − p_bad). Pearson chi-square over the histogram, tail
/// bins merged up to expected counts ≥ 5, against the Wilson–Hilferty
/// 99.98% χ² quantile.
#[test]
fn burst_length_histogram_matches_geometric_law() {
    let (p_bad, p_gb, p_bg) = (0.3, 0.5, 0.5);
    let drop = DropModel::Markov { p_bad, p_gb, p_bg };
    let q = p_bg * (1.0 - p_bad);
    assert_eq!(drop.mean_bad_burst(), Some(1.0 / q));
    let stats = chain_stats(drop, 50_000, 104);
    assert!(stats.bursts > 50_000, "need ~10⁵ bursts, got {}", stats.bursts);

    // Empirical mean burst vs 1/q (std of the mean ≈ 0.009 here; 5%
    // relative tolerance ≈ 15 std).
    let mean = stats.mean_burst().expect("bursts completed");
    let want = 1.0 / q;
    assert!(
        (mean - want).abs() / want < 0.05,
        "mean burst {mean:.4} vs closed form {want:.4}"
    );

    // Chi-square. Bin i of the histogram counts bursts of length i+1;
    // the last bin absorbs the overflow tail, and we merge from the top
    // until every cell expects ≥ 5 counts.
    let n = stats.bursts as f64;
    let bins = stats.burst_hist.len();
    let pmf = |i: usize| {
        if i + 1 == bins {
            (1.0 - q).powi(i as i32) // overflow: P(len > i)
        } else {
            q * (1.0 - q).powi(i as i32)
        }
    };
    let mut cells: Vec<(f64, f64)> = Vec::new(); // (observed, expected)
    let mut tail_obs = 0.0;
    let mut tail_exp = 0.0;
    for i in (0..bins).rev() {
        tail_obs += stats.burst_hist[i] as f64;
        tail_exp += n * pmf(i);
        if tail_exp >= 5.0 {
            cells.push((tail_obs, tail_exp));
            tail_obs = 0.0;
            tail_exp = 0.0;
        }
    }
    assert!(cells.len() >= 15, "degenerate binning: {} cells", cells.len());
    let chi2: f64 = cells.iter().map(|&(o, e)| (o - e) * (o - e) / e).sum();
    let dof = (cells.len() - 1) as f64;
    // Wilson–Hilferty: χ²_p(dof) ≈ dof·(1 − 2/(9·dof) + z·sqrt(2/(9·dof)))³.
    let h = 2.0 / (9.0 * dof);
    let crit = dof * (1.0 - h + 3.5 * h.sqrt()).powi(3);
    assert!(
        chi2 < crit,
        "burst law rejected: chi2 {chi2:.1} > crit {crit:.1} (dof {dof})"
    );
}

/// Memoryless specs (`markov:p,1,1` and plain i.i.d.) dispatch to the
/// historical Bernoulli draw and never sample the chain — no occupancy
/// counters, which is also what keeps them byte-identical to `prob:p`.
#[test]
fn memoryless_models_collect_no_chain_stats() {
    for drop in [
        DropModel::Iid(0.3),
        DropModel::Markov { p_bad: 0.3, p_gb: 1.0, p_bg: 1.0 },
    ] {
        let stats = chain_stats(drop, 500, 105);
        assert!(stats.is_empty(), "{drop}: chain sampled for a memoryless model");
        assert_eq!(stats.bursts, 0, "{drop}");
    }
}

/// The `bursty-geometric` preset end to end on the Monte-Carlo runner:
/// the merged occupancy counters reproduce π_B = 0.2, and the bursty
/// chain is excluded from the closed-form theory column with a message
/// that names the reason.
#[test]
fn bursty_geometric_preset_occupancy_through_the_runner() {
    let mut sc = find("bursty-geometric").expect("registry has bursty-geometric");
    assert_eq!(
        sc.impairments.drop,
        DropModel::Markov { p_bad: 0.2, p_gb: 0.25, p_bg: 0.25 },
        "preset changed under the test"
    );
    let err = theory_scope(&sc).expect_err("bursty chains have no i.i.d. closed form");
    assert!(err.contains("markov"), "{err}");
    // Shrunk schedule — the chain's physics is per-sample, not
    // per-horizon, so occupancy estimates only need enough samples.
    sc.runs = 2;
    sc.iters = 4_000;
    let (model, net, mc) = mc_parts(&sc).unwrap();
    let opts = scheduler_options(&sc);
    let res = mc.run_rust_opts(&model, &opts, || sc.algorithm.build(net.clone()));
    assert!(!res.linkstate.is_empty(), "bursty preset must tally the chain");
    let pi = sc.impairments.drop.mean_drop();
    assert_eq!(pi, 0.2, "symmetric redraw: π_B = p_bad");
    let emp = res.linkstate.bad_fraction().unwrap();
    // ~10⁶ sampled steps at τ = 4: std ≈ 0.0011; 0.02 is ≥ 18 std.
    assert!((emp - pi).abs() < 0.02, "occupancy {emp:.4} vs π_B {pi:.4}");
    let mb = res.linkstate.mean_burst().unwrap();
    let want = sc.impairments.drop.mean_bad_burst().unwrap();
    assert_eq!(want, 5.0, "preset's advertised mean burst");
    assert!((mb - want).abs() / want < 0.05, "mean burst {mb:.3} vs {want}");
}

/// Churn under `require_connected`: the active subgraph stays connected
/// through thousands of leave/join draws, while churn itself genuinely
/// happens. Without the veto the same pressure disconnects a path graph
/// almost immediately — the contract is the veto, not luck.
#[test]
fn churn_keeps_the_active_subgraph_connected_when_demanded() {
    let mut rng = Pcg64::new(31, 2);
    let graph = Graph::random_geometric(20, 0.3, &mut rng);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    let n = graph.n();
    let net = NetworkConfig { graph, c, a, mu: vec![5e-3; n], dim: 2 };
    let mut alg = Dcd::new(net.clone(), 1, 1);
    let dc = DynamicsConfig {
        leave: 0.05,
        join: 0.2,
        require_connected: true,
        ..DynamicsConfig::default()
    };
    let mut ds = DynamicsState::new(dc, &net, 31, 1);
    let mut seen = Vec::new();
    let mut stack = Vec::new();
    let mut min_active = n;
    for _ in 0..3_000 {
        ds.advance(&mut alg);
        min_active = min_active.min(ds.active_count());
        assert!(
            net.graph.is_connected_subset(ds.active(), &mut seen, &mut stack),
            "active subgraph disconnected under require_connected"
        );
    }
    assert!(min_active < n, "churn never removed a node in 3000 iterations");
    assert!(min_active >= 1, "the last node may never leave");

    // Contrast: the same pressure on a path graph with the veto off
    // must disconnect it (otherwise the assertion above is vacuous).
    let path = Graph::from_edges(8, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7)]);
    let c = combination_matrix(&path, Rule::Metropolis);
    let a = combination_matrix(&path, Rule::Metropolis);
    let net = NetworkConfig { graph: path, c, a, mu: vec![5e-3; 8], dim: 2 };
    let mut alg = Dcd::new(net.clone(), 1, 1);
    let dc = DynamicsConfig { leave: 0.05, join: 0.2, ..DynamicsConfig::default() };
    let mut ds = DynamicsState::new(dc, &net, 31, 1);
    let mut disconnected = false;
    for _ in 0..3_000 {
        ds.advance(&mut alg);
        if ds.active_count() > 0
            && !net.graph.is_connected_subset(ds.active(), &mut seen, &mut stack)
        {
            disconnected = true;
            break;
        }
    }
    assert!(disconnected, "no-veto churn never disconnected the path graph");
}

/// The `churn-grid` preset demands connectivity; its `[dynamics]`
/// section must survive the INI roundtrip and keep the demand.
#[test]
fn churn_grid_preset_roundtrips_its_connectivity_demand() {
    let sc = find("churn-grid").expect("registry has churn-grid");
    assert!(sc.dynamics.require_connected);
    assert!(sc.dynamics.leave > 0.0 && sc.dynamics.join > 0.0);
    let back = dcd_lms::scenario::Scenario::parse_str(&sc.to_ini_string()).unwrap();
    assert_eq!(back, sc, "churn-grid INI roundtrip");
    let err = theory_scope(&sc).expect_err("churn is outside the analysis scope");
    assert!(err.contains("dynamics"), "{err}");
}

/// The energy loop at the scenario level (DESIGN.md §13): a priced
/// radio debits the same capacitor as the compute cost, so the ENO
/// sleep fixed point stretches and the activation rate falls. Seeded
/// and direction-tested with a wide margin here — the exact closed-form
/// collapse factor is pinned at the unit level in
/// `rust/src/coordinator/wsn.rs`, and the bill's exactness in
/// `rust/tests/ledger.rs`.
#[test]
fn priced_radio_scenario_lowers_the_activation_rate() {
    use dcd_lms::energy::RadioEnergy;
    use dcd_lms::scenario::{wsn_sim, ScheduleMode};

    let mut sc = find("priced-wsn").expect("registry has priced-wsn");
    sc.mode = ScheduleMode::Wsn { duration: 20_000.0, sample_dt: 500.0 };

    let mut free_sc = sc.clone();
    free_sc.radio = RadioEnergy::zero();
    let free = wsn_sim(&free_sc).unwrap().run(sc.seed + 1);
    assert!(free.activations > 500, "workload too small to compare: {}", free.activations);
    assert_eq!(free.radio_joules, vec![0.0; 16], "the free radio must bill nothing");

    // A radio heavy enough to rival the Table-I compute cost: each DCD
    // activation on this ring(16, 2) exchanges ~768 bits, so 1e-5 J/bit
    // prices an activation at ~7.7e-3 J next to e_a = 5.4e-3 J — the
    // ENO fixed point must stretch visibly, not marginally.
    let mut heavy_sc = sc.clone();
    heavy_sc.radio = RadioEnergy { tx_j_per_bit: 1e-5, rx_j_per_bit: 1e-5 };
    let heavy = wsn_sim(&heavy_sc).unwrap().run(sc.seed + 1);
    assert!(
        (heavy.activations as f64) < 0.75 * free.activations as f64,
        "heavy radio {} not well below free {}",
        heavy.activations,
        free.activations
    );
    assert!(
        (heavy.activations as f64) > 0.15 * free.activations as f64,
        "heavy radio {} collapsed implausibly far below free {}",
        heavy.activations,
        free.activations
    );
    // Fewer activations means a genuinely smaller communication bill.
    assert!(heavy.ledger.bits() < free.ledger.bits());
    assert!(heavy.radio_joules.iter().sum::<f64>() > 0.0);

    // The preset's own gentle rates (50/20 nJ per bit) are a ~0.6%
    // perturbation of the per-activation energy: the bill must be
    // non-zero but the schedule must barely move. (No one-sided
    // ordering here: the shared event-order RNG decouples the two
    // sample paths, so only a closeness bound is sound.)
    let priced = wsn_sim(&sc).unwrap().run(sc.seed + 1);
    assert!(priced.radio_joules.iter().all(|&j| j >= 0.0));
    assert!(priced.radio_joules.iter().sum::<f64>() > 0.0);
    let ratio = priced.activations as f64 / free.activations as f64;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "gentle radio {} vs free {} (ratio {ratio:.3}) — a 50 nJ/bit price must not move the ENO schedule",
        priced.activations,
        free.activations
    );
}
