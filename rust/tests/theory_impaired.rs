//! Acceptance tests of the impaired-link theory engine (DESIGN.md §7):
//!
//! 1. At zero impairment the [`ImpairedMsdModel`] must degenerate to the
//!    ideal [`MsdModel`] — operator outputs, trajectories and steady
//!    states within 1e-12 across the (N, L) sweep the experiments use.
//! 2. For the `lossy-geometric` builtin (20 % per-link drops), the
//!    closed-form steady-state MSD must agree with the Monte-Carlo
//!    estimate within 1 dB — the impaired analogue of the paper's
//!    Fig. 3 (left) model-accuracy claim.

use dcd_lms::coordinator::impairments::{DropModel, Gating, LinkImpairments};
use dcd_lms::linalg::Mat;
use dcd_lms::rng::Pcg64;
use dcd_lms::scenario::{find, run_scenario};
use dcd_lms::theory::{ImpairedMsdModel, MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Graph, Rule};

fn setup(n: usize, l: usize, m: usize, mg: usize, mu: f64) -> TheorySetup {
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
    TheorySetup {
        n_nodes: n,
        dim: l,
        m,
        m_grad: mg,
        c,
        mu: vec![mu; n],
        sigma_u2: (0..n).map(|k| 0.8 + 0.1 * k as f64).collect(),
        sigma_v2: (0..n).map(|k| 1e-3 * (1.0 + 0.2 * k as f64)).collect(),
    }
}

fn random_sigma(nl: usize, rng: &mut Pcg64) -> Mat {
    let mut m = Mat::zeros(nl, nl);
    for i in 0..nl {
        for j in 0..nl {
            m[(i, j)] = rng.next_gaussian();
        }
    }
    let mt = m.transpose();
    &m * &mt
}

/// Zero impairment ⇒ the impaired model *is* the ideal model: operator
/// outputs and iterated trajectories agree to 1e-12 on N ∈ {2, 5, 10}.
#[test]
fn zero_impairment_matches_ideal_model() {
    let mut rng = Pcg64::new(2024, 0);
    let ideal_imp = LinkImpairments::ideal();
    for &n in &[2usize, 5, 10] {
        for &l in &[2usize, 5] {
            let m = ((3 * l) / 5).max(1);
            let mg = (l / 2).max(1);
            let s = setup(n, l, m, mg, 0.05);
            let ideal = MsdModel::new(s.clone());
            let impaired = ImpairedMsdModel::new(s, &ideal_imp).unwrap();
            let nl = n * l;

            // Operator equivalence on random symmetric weightings.
            for _ in 0..3 {
                let sigma = random_sigma(nl, &mut rng);
                let a = ideal.apply(&sigma);
                let b = impaired.apply(&sigma);
                let tol = 1e-12 * a.max_abs().max(1.0);
                let diff = (&b - &a).max_abs();
                assert!(diff < tol, "N={n} L={l}: operator diff {diff} (tol {tol})");
                let na = ideal.noise(&sigma);
                let nb = impaired.noise(&sigma);
                assert!(
                    (na - nb).abs() <= 1e-12 * na.abs().max(1.0),
                    "N={n} L={l}: noise {na} vs {nb}"
                );
            }

            // Trajectory + steady-state equivalence.
            let wo: Vec<f64> = (0..l).map(|j| 0.4 - 0.15 * j as f64).collect();
            let ta = ideal.trajectory(&wo, 400);
            let tb = impaired.trajectory(&wo, 400);
            for (i, (a, b)) in ta.msd.iter().zip(tb.msd.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * a.abs().max(1e-30),
                    "N={n} L={l} iter {i}: {a} vs {b}"
                );
            }
            let (sa, _) = ideal.steady_state(&wo, 1e-10, 20_000);
            let (sb, _) = impaired.steady_state(&wo, 1e-10, 20_000);
            assert!(
                (sa - sb).abs() <= 1e-12 * sa.abs(),
                "N={n} L={l}: steady state {sa} vs {sb}"
            );
        }
    }
}

/// The headline acceptance criterion: on the `lossy-geometric` builtin
/// the predicted steady-state MSD lands within 1 dB of the Monte-Carlo
/// estimate (the scenario runner computes both — simulation curve and
/// DESIGN.md §7 theory column — from the same scenario).
#[test]
fn lossy_geometric_prediction_within_one_db() {
    let mut sc = find("lossy-geometric").expect("registry has lossy-geometric");
    assert_eq!(sc.impairments.drop, DropModel::Iid(0.2), "preset changed under the test");
    // Shrunk schedule (physics untouched): more runs to tame MC noise,
    // a horizon that is still ≫ the convergence time constant.
    sc.runs = 16;
    sc.iters = 2_500;
    sc.record_every = 1;
    let out = run_scenario(&sc, None, true).unwrap();
    let theory_db = out.theory_steady_db.expect("lossy-geometric is theory-anchored");
    let gap = (theory_db - out.steady_db).abs();
    assert!(
        gap < 1.0,
        "steady state: theory {theory_db:.2} dB vs sim {:.2} dB (|gap| {gap:.2} dB)",
        out.steady_db
    );
    // And the transient tracks too (single-trace checkpoints, loose).
    let sim = &out.series[0];
    let theory = &out.series[1];
    for &i in &[400usize, 1200, 2400] {
        let s = sim.y[i - 1];
        let t = theory.y[i - 1];
        assert!((s - t).abs() < 3.0, "iter {i}: sim {s:.2} dB vs theory {t:.2} dB");
    }
}

/// Bernoulli gating is part of the closed form: duty-cycled variant of
/// the same preset still lands within tolerance (slightly looser — the
/// gate correlates the combiner across links).
#[test]
fn gated_lossy_geometric_prediction_tracks_simulation() {
    let mut sc = find("lossy-geometric").unwrap();
    sc.impairments.gating = Gating::Probabilistic(0.7);
    sc.runs = 12;
    sc.iters = 2_500;
    sc.record_every = 1;
    let out = run_scenario(&sc, None, true).unwrap();
    let theory_db = out.theory_steady_db.expect("probabilistic gating is in scope");
    let gap = (theory_db - out.steady_db).abs();
    assert!(
        gap < 1.5,
        "steady state: theory {theory_db:.2} dB vs sim {:.2} dB (|gap| {gap:.2} dB)",
        out.steady_db
    );
}

/// Quantization enters the prediction as a white floor Δ²/12 in the
/// driving covariance. The white-noise model's validity condition
/// (per-iteration increments ≳ Δ, DESIGN.md §7) does not hold at
/// paper-scale step sizes — the simulated mid-tread quantizer stalls in
/// its deadzone instead — so this test pins the *model*, not a tight
/// sim gap: the predicted floor must rise with Δ and the scenario
/// wiring must carry the quantized variant end to end.
#[test]
fn quantization_raises_the_predicted_floor() {
    let mut sc = find("lossy-geometric").unwrap();
    sc.impairments = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 2e-3,
        per_leg: false,
    };
    sc.runs = 4;
    sc.iters = 2_000;
    sc.record_every = 1;
    let quantized = run_scenario(&sc, None, true).unwrap();
    let q_theory = quantized.theory_steady_db.unwrap();
    sc.impairments.quant_step = 0.0;
    let clean = run_scenario(&sc, None, true).unwrap();
    let c_theory = clean.theory_steady_db.unwrap();
    assert!(q_theory > c_theory + 1.0, "theory floor: {q_theory} vs {c_theory}");
    // The simulated quantizer cannot do better than the ideal run.
    assert!(
        quantized.steady_db >= clean.steady_db - 0.3,
        "sim: quantized {} dB better than clean {} dB",
        quantized.steady_db,
        clean.steady_db
    );
}
