//! Acceptance tests for the directional message ledger (DESIGN.md §9):
//! hand-computed bills on a 3-node line topology, exact gating/drop
//! savings versus the legacy transmitter-only meter, and the billing
//! rules end-to-end through the round scheduler.

use dcd_lms::algorithms::{NetworkConfig, Purpose};
use dcd_lms::coordinator::impairments::{DropModel, Gating, LinkImpairments};
use dcd_lms::coordinator::RoundScheduler;
use dcd_lms::datamodel::DataModel;
use dcd_lms::energy::payload_bits;
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Graph, Rule};

const ITERS: usize = 50;

/// The 3-node line 0 — 1 — 2 (degrees 1, 2, 1; 4 directed links).
fn line_net(dim: usize) -> NetworkConfig {
    let graph = Graph::from_edges(3, &[(0, 1), (1, 2)]);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![0.05; 3], dim }
}

fn run_line(imp: Option<LinkImpairments>) -> dcd_lms::coordinator::RunResult {
    let mut rng = Pcg64::new(41, 0);
    let net = line_net(4);
    let model = DataModel::paper(3, 4, 1.0, 1.0, 1e-3, &mut rng);
    let mut sched = RoundScheduler::new(&model);
    sched.impairments = imp;
    // DCD with M = 2, M_grad = 1.
    let mut alg = dcd_lms::algorithms::Dcd::new(net, 2, 1);
    sched.run(&mut alg, ITERS, 17, 1)
}

/// Ideal links, DCD(M = 2, M∇ = 1): every directed link carries M
/// estimate scalars one way and M∇ gradient scalars back per iteration
/// — 3 scalars per directed link per iteration, 12 total, 64-bit
/// payloads. Every number below is hand-computed.
#[test]
fn ideal_line_bill_matches_hand_computation() {
    let res = run_line(None);
    let t = ITERS as u64;
    let l = &res.ledger;
    assert_eq!(l.scalars, 12 * t);
    assert_eq!(l.bits(), 12 * t * 64);
    assert_eq!(l.suppressed_scalars, 0);
    // Estimates: 4 directed links x M = 2; gradients: 4 x M∇ = 1.
    assert_eq!(l.purpose_scalars(Purpose::Estimate), 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Gradient), 4 * t);
    // Per transmitter: the end nodes each send M + M∇ = 3 (one
    // neighbour); the middle node sends 2 x (M + M∇) = 6.
    assert_eq!(l.per_node, vec![3 * t, 6 * t, 3 * t]);
    // Per directed link: M + M∇ = 3 each way on both edges; nothing on
    // the non-edge 0 <-> 2.
    assert_eq!(l.link_scalars(0, 1), 3 * t);
    assert_eq!(l.link_scalars(1, 0), 3 * t);
    assert_eq!(l.link_scalars(1, 2), 3 * t);
    assert_eq!(l.link_scalars(2, 1), 3 * t);
    assert_eq!(l.link_scalars(0, 2), 0);
    assert_eq!(l.link_scalars(2, 0), 0);
}

/// Every frame erased (`drop_prob = 1`): estimate broadcasts stay
/// billed (the transmitter spent the energy), but no request ever
/// arrives, so no gradient reply is ever computed, transmitted or
/// billed. The legacy transmitter-only meter billed those replies
/// anyway — the ledger's bill is strictly lower and the suppressed
/// counter reconciles the two exactly.
#[test]
fn fully_lossy_line_bill_matches_hand_computation() {
    let imp = LinkImpairments {
        drop: DropModel::Iid(1.0),
        gating: Gating::Always,
        quant_step: 0.0,
    };
    let res = run_line(Some(imp));
    let t = ITERS as u64;
    let l = &res.ledger;
    // Only the 4 x M = 8 estimate scalars per iteration are billed.
    assert_eq!(l.scalars, 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Estimate), 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Gradient), 0);
    // The 4 x M∇ = 4 dead replies per iteration are tracked, and the
    // legacy bill is reproduced exactly: strictly-lower billed bits is
    // the whole point of the directional ledger.
    assert_eq!(l.suppressed_scalars, 4 * t);
    assert_eq!(l.legacy_scalars(), 12 * t);
    assert!(l.scalars < l.legacy_scalars());
    assert_eq!(l.per_node, vec![2 * t, 4 * t, 2 * t]);
    assert_eq!(l.link_scalars(0, 1), 2 * t);
    assert_eq!(l.link_scalars(1, 0), 2 * t);
}

/// Everybody gated (`prob:0`): nothing transmits, nothing is billed —
/// and nothing is "suppressed" either, because the legacy mute-mask
/// meter got this case right already.
#[test]
fn fully_gated_line_bills_nothing() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Probabilistic(0.0),
        quant_step: 0.0,
    };
    let res = run_line(Some(imp));
    assert_eq!(res.ledger.scalars, 0);
    assert_eq!(res.ledger.bits(), 0);
    assert_eq!(res.ledger.suppressed_scalars, 0);
    assert_eq!(res.ledger.per_node, vec![0, 0, 0]);
}

/// Quantized payloads on the line: the same scalar counts, billed at
/// the Δ-grid width instead of 64 bits.
#[test]
fn quantized_line_bill_uses_grid_width() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 1e-3,
    };
    let res = run_line(Some(imp));
    let t = ITERS as u64;
    // 14 bits for the 1e-3 grid over the ±8 fixed-point range.
    let width = payload_bits(1e-3) as u64;
    assert_eq!(width, 14);
    assert_eq!(res.ledger.scalars, 12 * t);
    assert_eq!(res.ledger.bits(), 12 * t * width);
}

/// The probabilistic-gating bill sits strictly below the legacy bill
/// (a reply needs *both* ends on the air), and both bills reconcile
/// through the suppressed counter — the previously inexact gating
/// savings of DESIGN.md §4's old caveat, now exact.
#[test]
fn gated_line_savings_are_exact_and_strictly_larger_than_legacy() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Probabilistic(0.5),
        quant_step: 0.0,
    };
    let res = run_line(Some(imp));
    let l = &res.ledger;
    assert!(l.suppressed_scalars > 0, "no dead replies over {ITERS} iterations?");
    assert!(l.scalars < l.legacy_scalars());
    // Conservation still holds under gating.
    assert_eq!(l.per_node.iter().sum::<u64>(), l.scalars);
    assert_eq!(l.per_link.iter().sum::<u64>(), l.scalars);
    assert_eq!(l.per_purpose.iter().sum::<u64>(), l.scalars);
}
