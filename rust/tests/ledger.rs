//! Acceptance tests for the directional message ledger (DESIGN.md §9):
//! hand-computed bills on a 3-node line topology, exact gating/drop
//! savings versus the legacy transmitter-only meter, and the billing
//! rules end-to-end through the round scheduler.

use dcd_lms::algorithms::{NetworkConfig, Purpose};
use dcd_lms::coordinator::impairments::{DropModel, Gating, LinkImpairments};
use dcd_lms::coordinator::RoundScheduler;
use dcd_lms::datamodel::DataModel;
use dcd_lms::energy::payload_bits;
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Graph, Rule};

const ITERS: usize = 50;

/// The 3-node line 0 — 1 — 2 (degrees 1, 2, 1; 4 directed links).
fn line_net(dim: usize) -> NetworkConfig {
    let graph = Graph::from_edges(3, &[(0, 1), (1, 2)]);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![0.05; 3], dim }
}

fn run_line(imp: Option<LinkImpairments>) -> dcd_lms::coordinator::RunResult {
    let mut rng = Pcg64::new(41, 0);
    let net = line_net(4);
    let model = DataModel::paper(3, 4, 1.0, 1.0, 1e-3, &mut rng);
    let mut sched = RoundScheduler::new(&model);
    sched.impairments = imp;
    // DCD with M = 2, M_grad = 1.
    let mut alg = dcd_lms::algorithms::Dcd::new(net, 2, 1);
    sched.run(&mut alg, ITERS, 17, 1)
}

/// Ideal links, DCD(M = 2, M∇ = 1): every directed link carries M
/// estimate scalars one way and M∇ gradient scalars back per iteration
/// — 3 scalars per directed link per iteration, 12 total, 64-bit
/// payloads. Every number below is hand-computed.
#[test]
fn ideal_line_bill_matches_hand_computation() {
    let res = run_line(None);
    let t = ITERS as u64;
    let l = &res.ledger;
    assert_eq!(l.scalars, 12 * t);
    assert_eq!(l.bits(), 12 * t * 64);
    assert_eq!(l.suppressed_scalars, 0);
    // Estimates: 4 directed links x M = 2; gradients: 4 x M∇ = 1.
    assert_eq!(l.purpose_scalars(Purpose::Estimate), 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Gradient), 4 * t);
    // Per transmitter: the end nodes each send M + M∇ = 3 (one
    // neighbour); the middle node sends 2 x (M + M∇) = 6.
    assert_eq!(l.per_node, vec![3 * t, 6 * t, 3 * t]);
    // Per directed link: M + M∇ = 3 each way on both edges; nothing on
    // the non-edge 0 <-> 2.
    assert_eq!(l.link_scalars(0, 1), 3 * t);
    assert_eq!(l.link_scalars(1, 0), 3 * t);
    assert_eq!(l.link_scalars(1, 2), 3 * t);
    assert_eq!(l.link_scalars(2, 1), 3 * t);
    assert_eq!(l.link_scalars(0, 2), 0);
    assert_eq!(l.link_scalars(2, 0), 0);
}

/// Every frame erased (`drop_prob = 1`): estimate broadcasts stay
/// billed (the transmitter spent the energy), but no request ever
/// arrives, so no gradient reply is ever computed, transmitted or
/// billed. The legacy transmitter-only meter billed those replies
/// anyway — the ledger's bill is strictly lower and the suppressed
/// counter reconciles the two exactly.
#[test]
fn fully_lossy_line_bill_matches_hand_computation() {
    let imp = LinkImpairments {
        drop: DropModel::Iid(1.0),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    let res = run_line(Some(imp));
    let t = ITERS as u64;
    let l = &res.ledger;
    // Only the 4 x M = 8 estimate scalars per iteration are billed.
    assert_eq!(l.scalars, 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Estimate), 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Gradient), 0);
    // The 4 x M∇ = 4 dead replies per iteration are tracked, and the
    // legacy bill is reproduced exactly: strictly-lower billed bits is
    // the whole point of the directional ledger.
    assert_eq!(l.suppressed_scalars, 4 * t);
    assert_eq!(l.legacy_scalars(), 12 * t);
    assert!(l.scalars < l.legacy_scalars());
    assert_eq!(l.per_node, vec![2 * t, 4 * t, 2 * t]);
    assert_eq!(l.link_scalars(0, 1), 2 * t);
    assert_eq!(l.link_scalars(1, 0), 2 * t);
}

/// Everybody gated (`prob:0`): nothing transmits, nothing is billed —
/// and nothing is "suppressed" either, because the legacy mute-mask
/// meter got this case right already.
#[test]
fn fully_gated_line_bills_nothing() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Probabilistic(0.0),
        quant_step: 0.0,
        per_leg: false,
    };
    let res = run_line(Some(imp));
    assert_eq!(res.ledger.scalars, 0);
    assert_eq!(res.ledger.bits(), 0);
    assert_eq!(res.ledger.suppressed_scalars, 0);
    assert_eq!(res.ledger.per_node, vec![0, 0, 0]);
}

/// Quantized payloads on the line: the same scalar counts, billed at
/// the Δ-grid width instead of 64 bits.
#[test]
fn quantized_line_bill_uses_grid_width() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 1e-3,
        per_leg: false,
    };
    let res = run_line(Some(imp));
    let t = ITERS as u64;
    // 14 bits for the 1e-3 grid over the ±8 fixed-point range.
    let width = payload_bits(1e-3) as u64;
    assert_eq!(width, 14);
    assert_eq!(res.ledger.scalars, 12 * t);
    assert_eq!(res.ledger.bits(), 12 * t * width);
}

/// Per-leg erasures (DESIGN.md §13) with **no** drop process: the
/// independent reply draw is short-circuited (nothing to draw), so the
/// per-leg path is bit-identical to the legacy shared-erasure path —
/// trajectory and bill alike. This is the legacy-bytes contract the
/// shard golden test holds end-to-end.
#[test]
fn per_leg_with_no_drop_is_bit_identical_to_the_shared_path() {
    let shared = run_line(Some(LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    }));
    let per_leg = run_line(Some(LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: true,
    }));
    assert_eq!(shared.msd.len(), per_leg.msd.len());
    for (a, b) in shared.msd.iter().zip(per_leg.msd.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
    }
    assert_eq!(shared.ledger, per_leg.ledger);
}

/// Per-leg erasures at `drop_prob = 1`: both legs always erase, so the
/// bill is exactly the shared-erasure hand computation — estimates
/// billed (transmitter pays), requests never delivered, every reply
/// suppressed. The per-leg split changes *which* draws decide, never
/// what a certainly-dead link bills.
#[test]
fn per_leg_fully_lossy_line_matches_hand_computation() {
    let res = run_line(Some(LinkImpairments {
        drop: DropModel::Iid(1.0),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: true,
    }));
    let t = ITERS as u64;
    let l = &res.ledger;
    assert_eq!(l.scalars, 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Estimate), 8 * t);
    assert_eq!(l.purpose_scalars(Purpose::Gradient), 0);
    assert_eq!(l.suppressed_scalars, 4 * t);
    assert_eq!(l.legacy_scalars(), 12 * t);
    assert_eq!(l.per_node, vec![2 * t, 4 * t, 2 * t]);
}

/// The scenario-level radio bill (DESIGN.md §13) cross-foots exactly
/// against the directional ledger: with tx = rx = 2⁻²⁰ J/bit, every
/// billed bit costs the same dyadic amount whoever pays it, so the
/// summed per-node joules equal `2⁻²⁰ · ledger.bits()` bit-exactly (all
/// products and sums are exact dyadic f64 arithmetic). With a tx-only
/// price on a DCD network, only Estimate-purpose bits are transmitted
/// by the activating node — the per-purpose cross-foot.
#[test]
fn scenario_radio_bill_cross_foots_with_the_ledger() {
    let mut sc = dcd_lms::scenario::find("priced-wsn").unwrap();
    sc.runs = 2;
    sc.mode = dcd_lms::scenario::ScheduleMode::Wsn { duration: 8_000.0, sample_dt: 500.0 };
    let rate = (2.0f64).powi(-20);
    for drop in [DropModel::none(), DropModel::Iid(0.3)] {
        sc.impairments.drop = drop;
        // Symmetric price: total joules = rate x total billed bits.
        sc.radio = dcd_lms::energy::RadioEnergy { tx_j_per_bit: rate, rx_j_per_bit: rate };
        let out = dcd_lms::scenario::run_scenario(&sc, None, true).unwrap();
        let total: f64 = out.radio_joules.iter().sum();
        assert!(total > 0.0, "no radio spend under {drop}");
        assert_eq!(
            total.to_bits(),
            (rate * out.ledger.bits() as f64).to_bits(),
            "symmetric radio bill must equal rate x billed bits under {drop}"
        );
        // Transmit-only price: the activating node transmits exactly
        // the Estimate-purpose scalars (neighbours send the gradients).
        sc.radio = dcd_lms::energy::RadioEnergy { tx_j_per_bit: rate, rx_j_per_bit: 0.0 };
        let out = dcd_lms::scenario::run_scenario(&sc, None, true).unwrap();
        let total: f64 = out.radio_joules.iter().sum();
        let est_bits =
            out.ledger.purpose_scalars(Purpose::Estimate) * out.ledger.bits_per_scalar as u64;
        assert_eq!(
            total.to_bits(),
            (rate * est_bits as f64).to_bits(),
            "tx-only radio bill must equal rate x Estimate bits under {drop}"
        );
    }
}

/// A zero-rate `[energy]` section is the legacy code path: the canonical
/// INI omits the section entirely, and the written CSV artifacts are
/// byte-identical to a run that never mentioned the radio.
#[test]
fn zero_rate_radio_scenario_writes_legacy_bytes() {
    let mut sc = dcd_lms::scenario::find("priced-wsn").unwrap();
    sc.runs = 2;
    sc.mode = dcd_lms::scenario::ScheduleMode::Wsn { duration: 5_000.0, sample_dt: 500.0 };
    sc.radio = dcd_lms::energy::RadioEnergy::zero();
    let ini = sc.to_ini_string();
    assert!(!ini.contains("[energy]"), "zero-rate radio must not serialize: {ini}");
    let base = std::env::temp_dir().join("dcd_ledger_radio_zero");
    let (dir_a, dir_b) = (base.join("a"), base.join("b"));
    dcd_lms::scenario::run_scenario(&sc, Some(dir_a.to_str().unwrap()), true).unwrap();
    // The same scenario re-parsed from its canonical INI (no [energy]
    // section at all) must land byte-identical artifacts.
    let sc2 = dcd_lms::scenario::Scenario::parse_str(&ini).unwrap();
    dcd_lms::scenario::run_scenario(&sc2, Some(dir_b.to_str().unwrap()), true).unwrap();
    for file in ["priced-wsn.csv", "priced-wsn.json", "priced-wsn_ledger.csv"] {
        let a = std::fs::read(dir_a.join(file)).unwrap();
        let b = std::fs::read(dir_b.join(file)).unwrap();
        assert_eq!(a, b, "{file} differs between zero-rate and radio-free runs");
    }
    std::fs::remove_dir_all(&base).ok();
}

/// The probabilistic-gating bill sits strictly below the legacy bill
/// (a reply needs *both* ends on the air), and both bills reconcile
/// through the suppressed counter — the previously inexact gating
/// savings of DESIGN.md §4's old caveat, now exact.
#[test]
fn gated_line_savings_are_exact_and_strictly_larger_than_legacy() {
    let imp = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Probabilistic(0.5),
        quant_step: 0.0,
        per_leg: false,
    };
    let res = run_line(Some(imp));
    let l = &res.ledger;
    assert!(l.suppressed_scalars > 0, "no dead replies over {ITERS} iterations?");
    assert!(l.scalars < l.legacy_scalars());
    // Conservation still holds under gating.
    assert_eq!(l.per_node.iter().sum::<u64>(), l.scalars);
    assert_eq!(l.per_link.iter().sum::<u64>(), l.scalars);
    assert_eq!(l.per_purpose.iter().sum::<u64>(), l.scalars);
}
