//! Property tests over coordinator invariants (DESIGN.md §5), using the
//! in-tree harness (`dcd_lms::testing` — the offline `proptest`
//! substitute).

use dcd_lms::algorithms::{
    Algorithm, CommMeter, Dcd, DiffusionLms, NetworkConfig, PartialDiffusion, Rcd, StepData,
};
use dcd_lms::coordinator::impairments::{DropModel, Gating, LinkImpairments};
use dcd_lms::coordinator::runner::{MonteCarlo, SchedulerOptions};
use dcd_lms::datamodel::DataModel;
use dcd_lms::linalg::Mat;
use dcd_lms::rng::Pcg64;
use dcd_lms::testing::{check, usize_in, Gen, PropConfig};
use dcd_lms::topology::{combination_matrix, Graph, Rule};

/// A random network + compression setting.
#[derive(Debug, Clone)]
struct Case {
    n: usize,
    l: usize,
    m: usize,
    mg: usize,
    hops: usize,
    seed: u64,
}

fn case_gen() -> Gen<Case> {
    Gen::new(|rng, size| {
        let n = 3 + rng.next_below(3 + (size as usize * 7) / 255 + 1);
        let l = 1 + rng.next_below(1 + (size as usize * 9) / 255 + 1);
        Case {
            n,
            l,
            m: 1 + rng.next_below(l),
            mg: 1 + rng.next_below(l),
            hops: 1 + rng.next_below(((n - 1) / 2).max(1)),
            seed: rng.next_u64(),
        }
    })
}

fn net_for(case: &Case) -> NetworkConfig {
    let graph = Graph::ring(case.n, case.hops);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    NetworkConfig { graph, c, a, mu: vec![0.03; case.n], dim: case.l }
}

fn drive(alg: &mut dyn Algorithm, case: &Case, iters: usize, comm: &mut CommMeter) {
    let mut rng = Pcg64::new(case.seed, 1);
    let (n, l) = (case.n, case.l);
    let mut u = vec![0.0; n * l];
    let mut d = vec![0.0; n];
    for _ in 0..iters {
        for x in u.iter_mut() {
            *x = rng.next_gaussian();
        }
        for dk in d.iter_mut() {
            *dk = rng.next_gaussian();
        }
        alg.step(StepData { u: &u, d: &d }, &mut rng, comm);
    }
}

/// The comm meter must equal the closed-form expected scalar counts for
/// every algorithm whose traffic is deterministic given the topology.
#[test]
fn prop_comm_meter_matches_closed_form() {
    check(&PropConfig { cases: 40, seed: 11 }, &case_gen(), |case| {
        let net = net_for(case);
        let iters = 3;
        for alg in [
            Box::new(Dcd::new(net.clone(), case.m, case.mg)) as Box<dyn Algorithm>,
            Box::new(Dcd::cd(net.clone(), case.m)),
            Box::new(DiffusionLms::new(net.clone())),
            Box::new(PartialDiffusion::new(net.clone(), case.m)),
            Box::new(Rcd::new(net.clone(), 1 + case.seed as usize % 2)),
        ] {
            let mut alg = alg;
            let mut comm = CommMeter::new(case.n);
            drive(alg.as_mut(), case, iters, &mut comm);
            let expect = alg.expected_scalars_per_iter() * iters as f64;
            if (comm.scalars() as f64 - expect).abs() > 1e-9 {
                return Err(format!(
                    "{}: metered {} vs expected {}",
                    alg.name(),
                    comm.scalars(),
                    expect
                ));
            }
            // Ledger conservation: the per-node, per-link and
            // per-purpose breakdowns each sum back to the total, and
            // billed bits are scalars x width (DESIGN.md §9).
            let ledger = comm.ledger();
            if ledger.per_node.iter().sum::<u64>() != ledger.scalars
                || ledger.per_link.iter().sum::<u64>() != ledger.scalars
                || ledger.per_purpose.iter().sum::<u64>() != ledger.scalars
                || ledger.bits() != ledger.scalars * ledger.bits_per_scalar as u64
            {
                return Err(format!("{}: ledger breakdowns do not cross-foot", alg.name()));
            }
            // Billing stays on real directed edges.
            for src in 0..case.n {
                for dst in 0..case.n {
                    if ledger.link_scalars(src, dst) > 0
                        && !net.graph.neighbors(src).contains(&dst)
                    {
                        return Err(format!(
                            "{}: billed off-graph link {src}->{dst}",
                            alg.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The paper's compression-ratio formulas, as exposed by each algorithm.
#[test]
fn prop_compression_ratio_formulas() {
    check(&PropConfig { cases: 60, seed: 13 }, &case_gen(), |case| {
        let net = net_for(case);
        let l = case.l as f64;
        let dcd = Dcd::new(net.clone(), case.m, case.mg);
        let want = 2.0 * l / (case.m + case.mg) as f64;
        if (dcd.compression_ratio().unwrap() - want).abs() > 1e-12 {
            return Err(format!("dcd ratio {} != {want}", dcd.compression_ratio().unwrap()));
        }
        let cd = Dcd::cd(net.clone(), case.m);
        let want = 2.0 * l / (case.m as f64 + l);
        if (cd.compression_ratio().unwrap() - want).abs() > 1e-12 {
            return Err("cd ratio mismatch".into());
        }
        let pd = PartialDiffusion::new(net, case.m);
        let want = 2.0 * l / case.m as f64;
        if (pd.compression_ratio().unwrap() - want).abs() > 1e-12 {
            return Err("partial ratio mismatch".into());
        }
        Ok(())
    });
}

/// Combine steps are convex: with every node holding the same vector and
/// zero step size, one iteration must leave the state unchanged, for any
/// masks/selections any algorithm draws.
#[test]
fn prop_consensus_is_fixed_point_at_zero_step() {
    check(&PropConfig { cases: 40, seed: 17 }, &case_gen(), |case| {
        let mut net = net_for(case);
        net.mu = vec![0.0; case.n];
        let mut rng = Pcg64::new(case.seed, 2);
        let constant = 1.0 + rng.next_f64();
        for alg in [
            Box::new(Dcd::new(net.clone(), case.m, case.mg)) as Box<dyn Algorithm>,
            Box::new(DiffusionLms::new(net.clone())),
            Box::new(PartialDiffusion::new(net.clone(), case.m)),
            Box::new(Rcd::new(net.clone(), 1)),
        ] {
            let mut alg = alg;
            // Seed every node with the same vector by running one
            // zero-step iteration from a crafted state: instead, verify
            // via the residual route — zero-step keeps w = 0, then any
            // combine of equal vectors stays equal.
            let (n, l) = (case.n, case.l);
            let u = vec![0.5; n * l];
            let d = vec![constant; n];
            let mut comm = CommMeter::new(n);
            let mut rng2 = Pcg64::new(case.seed, 3);
            alg.step(StepData { u: &u, d: &d }, &mut rng2, &mut comm);
            for (i, &w) in alg.weights().iter().enumerate() {
                if w.abs() > 1e-12 {
                    return Err(format!("{}: w[{i}] = {w} after zero-step", alg.name()));
                }
            }
        }
        Ok(())
    });
}

/// Estimates stay finite over long horizons when μ is far below the
/// stability bound (failure injection: heavy-tailed-ish data via
/// occasional large regressors).
#[test]
fn prop_estimates_stay_finite_below_bound() {
    check(&PropConfig { cases: 15, seed: 23 }, &case_gen(), |case| {
        let net = net_for(case);
        let mut alg = Dcd::new(net, case.m, case.mg);
        let mut rng = Pcg64::new(case.seed, 4);
        let (n, l) = (case.n, case.l);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut comm = CommMeter::new(n);
        for i in 0..400 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian() * if i % 37 == 0 { 5.0 } else { 1.0 };
            }
            for dk in d.iter_mut() {
                *dk = rng.next_gaussian();
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        if alg.weights().iter().all(|w| w.is_finite()) {
            Ok(())
        } else {
            Err("non-finite weight".into())
        }
    });
}

/// Mask popcounts: every drawn H row has exactly M ones — via the bus
/// protocol (message sizes are exactly M and M_grad).
#[test]
fn prop_message_sizes_exact() {
    check(&PropConfig { cases: 30, seed: 29 }, &case_gen(), |case| {
        use dcd_lms::coordinator::bus::{Bus, Message};
        use dcd_lms::coordinator::agent::{Agent, AgentConfig};
        let net = net_for(case);
        let n = case.n;
        let bus = Bus::new(n);
        let mut agents: Vec<Agent> = (0..n)
            .map(|k| {
                let neighbors: Vec<usize> = net.graph.neighbors(k).to_vec();
                Agent::new(
                    AgentConfig {
                        id: k,
                        dim: case.l,
                        m: case.m,
                        m_grad: case.mg,
                        mu: 0.01,
                        c_self: net.c[(k, k)],
                        c_neighbors: neighbors.iter().map(|&l| net.c[(l, k)]).collect(),
                        a_self: net.a[(k, k)],
                        a_neighbors: neighbors.iter().map(|&l| net.a[(l, k)]).collect(),
                        neighbors,
                    },
                    case.seed,
                )
            })
            .collect();
        for ag in agents.iter_mut() {
            ag.observe(&vec![0.3; case.l], 0.7);
            ag.phase_broadcast(&bus, true);
        }
        // Every estimate message must carry exactly M scalars.
        for k in 0..n {
            for msg in bus.drain(k) {
                match msg {
                    Message::Estimate { body, .. } => {
                        if body.len() != case.m {
                            return Err(format!(
                                "estimate carries {} scalars, want {}",
                                body.len(),
                                case.m
                            ));
                        }
                    }
                    Message::Gradient { body, .. } => {
                        if body.len() != case.mg {
                            return Err("bad gradient size".into());
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// RCD reweighting: combine weights stay a partition of unity for any
/// selection, so a network in consensus stays in consensus even with
/// nonzero step size and noiseless consistent data.
#[test]
fn prop_rcd_consensus_preserved() {
    check(&PropConfig { cases: 30, seed: 31 }, &case_gen(), |case| {
        let net = net_for(case);
        let mut alg = Rcd::new(net, 1 + case.seed as usize % 3);
        let (n, l) = (case.n, case.l);
        // Put the network at the true optimum w° and feed consistent data.
        let mut rng = Pcg64::new(case.seed, 5);
        let wo: Vec<f64> = (0..l).map(|_| rng.next_gaussian()).collect();
        // Drive to near-consensus first.
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut comm = CommMeter::new(n);
        for _ in 0..600 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = u[k * l..(k + 1) * l]
                    .iter()
                    .zip(wo.iter())
                    .map(|(a, b)| a * b)
                    .sum();
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        let msd = alg.msd(&wo);
        if msd < 1e-3 {
            Ok(())
        } else {
            Err(format!("rcd failed to reach consensus: msd {msd}"))
        }
    });
}

/// Lane dispatch is safe for every algorithm: those without a batched
/// face (RCD's neighbour polling, partial diffusion, DCD over noisy
/// links) fall back to the scalar scheduler per run range, so asking
/// for lanes > 1 must reproduce the serial bytes exactly — MSD trace,
/// steady state and ledger alike (DESIGN.md §14).
#[test]
fn prop_scalar_fallback_under_lanes_reproduces_serial_bytes() {
    check(&PropConfig { cases: 10, seed: 53 }, &case_gen(), |case| {
        let net = net_for(case);
        let mut rng = Pcg64::new(case.seed, 0);
        let model = DataModel::paper(case.n, case.l, 0.8, 1.2, 1e-3, &mut rng);
        let imp = LinkImpairments {
            drop: DropModel::Iid(0.2),
            gating: Gating::Probabilistic(0.9),
            quant_step: 0.0,
            per_leg: false,
        };
        let m_links = 1 + case.seed as usize % 2;
        let builds: [(&str, Box<dyn Fn() -> Box<dyn Algorithm> + Sync>); 3] = [
            ("rcd", {
                let net = net.clone();
                Box::new(move || Box::new(Rcd::new(net.clone(), m_links)) as Box<dyn Algorithm>)
            }),
            ("partial", {
                let (net, m) = (net.clone(), case.m);
                Box::new(move || {
                    Box::new(PartialDiffusion::new(net.clone(), m)) as Box<dyn Algorithm>
                })
            }),
            ("noisy-dcd", {
                let (net, m, mg) = (net.clone(), case.m, case.mg);
                Box::new(move || {
                    Box::new(Dcd::new(net.clone(), m, mg).with_link_noise(0.05))
                        as Box<dyn Algorithm>
                })
            }),
        ];
        for opts in [
            SchedulerOptions::default(),
            SchedulerOptions::from_impairments(Some(&imp)),
        ] {
            let mc = MonteCarlo {
                runs: 5,
                iters: 40,
                seed: case.seed ^ 0x5bd1,
                record_every: 1,
                threads: 1,
            };
            for (name, make) in &builds {
                let serial = mc.run_rust_serial_opts(&model, &opts, &**make);
                let laned = mc.run_rust_lanes_opts(&model, &opts, 4, &**make);
                if laned.msd != serial.msd {
                    return Err(format!("{name}: MSD diverged under lanes"));
                }
                if laned.steady_state.to_bits() != serial.steady_state.to_bits() {
                    return Err(format!("{name}: steady state diverged under lanes"));
                }
                if laned.ledger != serial.ledger {
                    return Err(format!("{name}: ledger diverged under lanes"));
                }
            }
        }
        Ok(())
    });
}

/// Metropolis matrices remain doubly stochastic for arbitrary connected
/// ring topologies (substrate invariant used throughout the theory).
#[test]
fn prop_metropolis_doubly_stochastic() {
    check(&PropConfig { cases: 80, seed: 37 }, &case_gen(), |case| {
        let graph = Graph::ring(case.n, case.hops);
        let sparse = combination_matrix(&graph, Rule::Metropolis);
        let a = sparse.to_dense();
        // Sparse accessors must agree with the dense view they abstract.
        for (k, (cs, rs)) in sparse.col_sums().iter().zip(sparse.row_sums()).enumerate() {
            if (cs - 1.0).abs() > 1e-9 || (rs - 1.0).abs() > 1e-9 {
                return Err(format!("sparse node {k}: col {cs} row {rs}"));
            }
        }
        for k in 0..case.n {
            let col: f64 = (0..case.n).map(|l| a[(l, k)]).sum();
            let row: f64 = a.row(k).iter().sum();
            if (col - 1.0).abs() > 1e-9 || (row - 1.0).abs() > 1e-9 {
                return Err(format!("node {k}: col {col} row {row}"));
            }
            if a[(k, k)] < 0.0 {
                return Err("negative diagonal".into());
            }
        }
        // Spectral radius of a doubly stochastic matrix is 1.
        let rho = dcd_lms::linalg::spectral_radius(&a, 500);
        if (rho - 1.0).abs() > 1e-6 {
            return Err(format!("rho {rho}"));
        }
        let _ = Mat::eye(2);
        Ok(())
    });
}

/// CSR kernels agree with dense linear algebra on random geometric
/// graphs across three decades of N — the correctness base under the
/// sparse fast path (DESIGN.md §10).
#[test]
fn sparse_kernels_match_dense_on_geometric_graphs() {
    use dcd_lms::linalg::SparseMat;
    for &(n, radius, seed) in &[(10usize, 0.5, 41u64), (50, 0.25, 42), (200, 0.12, 43)] {
        let mut rng = Pcg64::new(seed, 0);
        let graph = Graph::random_geometric(n, radius, &mut rng);
        let dense = combination_matrix(&graph, Rule::Metropolis).to_dense();
        let sparse = SparseMat::from_dense(&dense);
        assert_eq!(sparse.to_dense(), dense, "N={n}: from_dense/to_dense roundtrip");

        // spmv vs dense matvec.
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let want = dense.matvec(&x);
        let got = sparse.spmv(&x);
        let mut got_into = vec![0.0; n];
        sparse.spmv_into(&x, &mut got_into);
        for k in 0..n {
            assert!((got[k] - want[k]).abs() < 1e-12, "N={n} spmv row {k}");
            assert!((got_into[k] - want[k]).abs() < 1e-12, "N={n} spmv_into row {k}");
        }

        // transpose / transpose_into vs the dense transpose.
        let dt = dense.transpose();
        assert_eq!(sparse.transpose().to_dense(), dt, "N={n}: transpose");
        let mut tbuf = SparseMat::zeros(1, 1);
        sparse.transpose_into(&mut tbuf);
        assert_eq!(tbuf.to_dense(), dt, "N={n}: transpose_into");
    }
}

/// The O(E) in-place effective-matrix rebuild must match a direct dense
/// reconstruction from the same drawn outcomes, on every graph size.
#[test]
fn effective_rebuild_matches_dense_reconstruction() {
    use dcd_lms::coordinator::impairments::{DropModel, Gating, ImpairmentState, LinkImpairments};
    let imp = LinkImpairments {
        drop: DropModel::Iid(0.3),
        gating: Gating::Probabilistic(0.8),
        quant_step: 0.0,
        per_leg: false,
    };
    for &(n, radius, seed) in &[(10usize, 0.5, 51u64), (50, 0.25, 52), (200, 0.12, 53)] {
        let mut rng = Pcg64::new(seed, 0);
        let graph = Graph::random_geometric(n, radius, &mut rng);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Uniform);
        let a0 = a.to_dense();
        let c0 = c.to_dense();
        let net = NetworkConfig { graph, c, a, mu: vec![1e-2; n], dim: 3 };
        let mut alg = Dcd::new(net.clone(), 2, 1);
        let mut comm = CommMeter::new(n);
        let mut state = ImpairmentState::new(&net, seed, 9);
        for iter in 0..5 {
            state.begin_iteration(&imp, &mut alg, &mut comm);
            // Dense reconstruction from the published outcomes: dead
            // l → k links move their mass to the receiver's diagonal; a
            // silent receiver also collapses its whole C column.
            let mut a_want = a0.clone();
            let mut c_want = c0.clone();
            for k in 0..n {
                for &lnb in net.graph.neighbors(k) {
                    let dead = !state.delivered().delivered(lnb, k);
                    if dead {
                        a_want[(k, k)] += a_want[(lnb, k)];
                        a_want[(lnb, k)] = 0.0;
                    }
                    if dead || state.silent()[k] {
                        c_want[(k, k)] += c_want[(lnb, k)];
                        c_want[(lnb, k)] = 0.0;
                    }
                }
            }
            let (a_eff, c_eff) = {
                let netr = alg.network();
                (netr.a.to_dense(), netr.c.to_dense())
            };
            let da = (&a_eff - &a_want).max_abs();
            let dc = (&c_eff - &c_want).max_abs();
            assert!(da < 1e-12, "N={n} iter {iter}: A rebuild off by {da}");
            assert!(dc < 1e-12, "N={n} iter {iter}: C rebuild off by {dc}");
            // Column mass is conserved exactly by the reallocation.
            for (k, s) in dcd_lms::topology::col_sums(&a_eff).iter().enumerate() {
                assert!((s - 1.0).abs() < 1e-9, "N={n} col {k} sum {s}");
            }
        }
        state.restore(&mut alg, &mut comm);
        assert_eq!(alg.network().a.to_dense(), a0, "restore puts A back");
    }
}

/// Adaptive combination weights (DESIGN.md §12): on random geometric
/// graphs with arbitrary per-link delivery rates, both policies keep
/// every receiver's incoming mass at exactly the pristine total, stay
/// entrywise non-negative, and degenerate to the pristine weights when
/// no impairment has been observed (all rates 1).
#[test]
fn adaptive_reweight_preserves_row_mass_and_degenerates_to_static() {
    use dcd_lms::coordinator::impairments::{adaptive_reweight, AdaptivePolicy};
    for &(n, radius, seed) in &[(10usize, 0.5, 61u64), (50, 0.25, 62), (200, 0.12, 63)] {
        let mut rng = Pcg64::new(seed, 0);
        let graph = Graph::random_geometric(n, radius, &mut rng);
        for rule in [Rule::Metropolis, Rule::Uniform] {
            let base = combination_matrix(&graph, rule);
            let mut rates = vec![0.0; 0];
            let mut row_off = vec![0usize; n + 1];
            for k in 0..n {
                row_off[k] = rates.len();
                for _ in graph.neighbors(k) {
                    rates.push(rng.next_f64());
                }
            }
            row_off[n] = rates.len();
            let rate = |k: usize, slot: usize| rates[row_off[k] + slot];
            for policy in [AdaptivePolicy::Metropolis, AdaptivePolicy::Acw] {
                let rw = adaptive_reweight(policy, &graph, &base, rate);
                for k in 0..n {
                    let (_, want) = base.row(k);
                    let (_, got) = rw.row(k);
                    let w: f64 = want.iter().sum();
                    let g: f64 = got.iter().sum();
                    assert!(
                        (w - g).abs() < 1e-12,
                        "N={n} {rule:?} {policy:?} row {k}: mass {w} -> {g}"
                    );
                    for (i, &v) in got.iter().enumerate() {
                        assert!(v >= -1e-15, "N={n} {policy:?} row {k} entry {i}: {v}");
                    }
                }
                // All-delivered rates: bit-identical to the pristine
                // combiner (the no-impairment degenerate case).
                let identity = adaptive_reweight(policy, &graph, &base, |_, _| 1.0);
                assert_eq!(identity.vals(), base.vals(), "N={n} {rule:?} {policy:?}");
            }
            // Static is a plain copy whatever the rates say.
            let st = adaptive_reweight(AdaptivePolicy::Static, &graph, &base, rate);
            assert_eq!(st.vals(), base.vals(), "N={n} {rule:?} static");
        }
    }
}
