//! Fig. 4 workflow: the energy-harvesting WSN. Runs the six algorithm
//! settings of Experiment 3 on a (scaled-down unless --full) hillside
//! network and prints the energy/accuracy table.
//!
//! ```bash
//! cargo run --release --example wsn_energy -- --fast
//! ```

use dcd_lms::config::Exp3Config;
use dcd_lms::experiments::run_exp3;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");

    let mut cfg = Exp3Config::default();
    if !full {
        // Scaled-down network, same physics.
        cfg.n_nodes = 24;
        cfg.dim = 16;
        cfg.radius = 0.32;
        cfg.duration = 40_000.0;
        cfg.sample_dt = 800.0;
        cfg.runs = 2;
        cfg.cd_m = 10; // keep CD's ratio ≈ 2L/(M+L) ≈ 1.23 at L=16
        cfg.partial_m = 2;
        cfg.dcd_m = 1;
        cfg.dcd_m_grad = 1; // r = 2L/(M+M∇) = 16 ≈ the paper's 20
    }

    println!(
        "WSN: N={} L={} horizon {:.0}s ({} runs){}\n",
        cfg.n_nodes,
        cfg.dim,
        cfg.duration,
        cfg.runs,
        if full { "" } else { "  [scaled; pass --full for the paper's N=80 L=40]" }
    );
    let out = run_exp3(&cfg, Some("results"), false)?;

    println!("\nsummary (more activations = cheaper active phase = faster convergence):");
    println!("{:<18} {:>12} {:>16}", "algorithm", "final MSD", "activations/run");
    for (label, db, act) in &out.summary {
        println!("{label:<18} {db:>9.2} dB {act:>16.0}");
    }
    Ok(())
}
