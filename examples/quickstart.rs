//! Quickstart: build a small adaptive network, run DCD next to plain
//! diffusion LMS, and compare accuracy vs communication cost.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dcd_lms::algorithms::{Algorithm, Dcd, DiffusionLms, NetworkConfig};
use dcd_lms::coordinator::MonteCarlo;
use dcd_lms::datamodel::DataModel;
use dcd_lms::metrics::to_db;
use dcd_lms::rng::Pcg64;
use dcd_lms::topology::{combination_matrix, Graph, Rule};

fn main() {
    // 1. A 12-node network with Metropolis combination weights.
    let n = 12;
    let l = 8;
    let graph = Graph::ring(n, 2);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    let net = NetworkConfig { graph, c, a, mu: vec![0.01; n], dim: l };
    net.validate().expect("stochastic combiners");

    // 2. Streaming data d = u^T w° + v at every node.
    let mut rng = Pcg64::new(7, 0);
    let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);

    // 3. Monte-Carlo the learning curves.
    let mc = MonteCarlo { runs: 10, iters: 4_000, seed: 42, record_every: 1, threads: 0 };

    let full = mc.run_rust(&model, || Box::new(DiffusionLms::new(net.clone())));
    // DCD shares 2 of 8 estimate entries and 2 of 8 gradient entries:
    // compression ratio 2L/(M+M∇) = 4.
    let dcd = mc.run_rust(&model, || Box::new(Dcd::new(net.clone(), 2, 2)));

    let full_cost = DiffusionLms::new(net.clone()).expected_scalars_per_iter();
    let dcd_alg = Dcd::new(net, 2, 2);
    let dcd_cost = dcd_alg.expected_scalars_per_iter();

    println!("algorithm        steady-state MSD   scalars/iteration");
    println!(
        "diffusion LMS    {:>10.2} dB      {:>8.0}",
        to_db(full.steady_state),
        full_cost
    );
    println!(
        "DCD (M=2, M∇=2)  {:>10.2} dB      {:>8.0}   ({}x compression)",
        to_db(dcd.steady_state),
        dcd_cost,
        dcd_alg.compression_ratio().unwrap()
    );
    println!(
        "\nDCD trades {:.1} dB of steady-state MSD for a {:.0}x cut in traffic.",
        to_db(dcd.steady_state) - to_db(full.steady_state),
        full_cost / dcd_cost
    );
}
