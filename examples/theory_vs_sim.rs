//! Fig. 3 (left) in miniature: the closed-form mean-square model (paper
//! §III-B) against Monte-Carlo simulation for DCD on the paper's 10-node
//! network, printed as an ASCII learning-curve table.
//!
//! ```bash
//! cargo run --release --example theory_vs_sim
//! ```

use dcd_lms::algorithms::{Dcd, NetworkConfig};
use dcd_lms::coordinator::MonteCarlo;
use dcd_lms::datamodel::DataModel;
use dcd_lms::metrics::to_db;
use dcd_lms::rng::Pcg64;
use dcd_lms::theory::{MeanModel, MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Combiner, Graph, Rule};

fn main() {
    let (n, l, m, mg) = (10, 5, 3, 1);
    let mu = 5e-3; // faster than the paper's 1e-3 so the demo is quick
    let iters = 8_000;

    let graph = Graph::paper_ten_node();
    let c = combination_matrix(&graph, Rule::Metropolis);
    let mut rng = Pcg64::new(2017, 0);
    let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);

    let setup = TheorySetup {
        n_nodes: n,
        dim: l,
        m,
        m_grad: mg,
        c: c.to_dense(),
        mu: vec![mu; n],
        sigma_u2: model.sigma_u2.clone(),
        sigma_v2: model.sigma_v2.clone(),
    };
    let mean = MeanModel::new(setup.clone());
    println!(
        "DCD on the paper's 10-node network: M={m}, M∇={mg}, μ={mu}  (ρ(𝓑)={:.4})",
        mean.rho()
    );

    let theory = MsdModel::new(setup).trajectory(&model.wo, iters);

    let net = NetworkConfig { graph, c, a: Combiner::eye(n), mu: vec![mu; n], dim: l };
    let mc = MonteCarlo { runs: 20, iters, seed: 1, record_every: 1, threads: 0 };
    let sim = mc.run_rust(&model, || Box::new(Dcd::new(net.clone(), m, mg)));

    println!("\n   iter    theory (dB)    sim (dB)    |gap|");
    for &i in &[1usize, 50, 200, 500, 1000, 2000, 4000, 8000] {
        let t = to_db(theory.msd[i - 1]);
        let s = to_db(sim.msd[i - 1]);
        println!("{i:>7}    {t:>8.2}      {s:>8.2}    {:>5.2}", (t - s).abs());
    }
    let gap = (to_db(theory.steady_state) - to_db(sim.steady_state)).abs();
    println!("\nsteady-state gap: {gap:.2} dB (paper's model-accuracy claim: ≲ 1 dB)");
    assert!(gap < 2.0, "theory and simulation diverged");
}
