//! Fig. 3 (center/right) workflow: sweep the compression ratio and watch
//! the accuracy/traffic trade-off, using the AOT-compiled xla engine when
//! artifacts are available (pass --rust to force the message-level
//! engine; pass --fast for a smoke-sized run).
//!
//! ```bash
//! make artifacts && cargo run --release --example compression_sweep
//! ```

use dcd_lms::config::Exp2Config;
use dcd_lms::experiments::{run_exp2, Engine};
use dcd_lms::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let force_rust = args.iter().any(|a| a == "--rust");

    let mut cfg = Exp2Config::default();
    if fast {
        cfg.n_nodes = 16;
        cfg.dim = 16;
        cfg.runs = 4;
        cfg.iters = 800;
        cfg.cd_m_values = vec![12, 8, 4];
        cfg.dcd_pairs = vec![(8, 8), (4, 4), (2, 2)];
    }

    // The xla engine needs an artifact matching (N, L); the shipped
    // manifest covers the paper shape (50, 50). Fall back to rust
    // otherwise.
    let engine = if force_rust || fast || !dcd_lms::runtime::xla_available() {
        Engine::Rust
    } else {
        match Runtime::open_default() {
            Ok(rt) if rt.manifest().find("dcd", "exp2").is_some() => Engine::Xla,
            _ => {
                eprintln!("(artifacts unavailable — falling back to the rust engine)");
                Engine::Rust
            }
        }
    };

    println!(
        "compression sweep on N={} L={} ({:?} engine)\n",
        cfg.n_nodes, cfg.dim, engine
    );
    let out = run_exp2(&cfg, engine, Some("results"), false)?;

    println!("\nratio -> steady-state MSD (dB)");
    println!("  CD : {:?}", out
        .cd
        .iter()
        .map(|(r, d)| format!("{r:.2}:{d:.1}"))
        .collect::<Vec<_>>());
    println!("  DCD: {:?}", out
        .dcd
        .iter()
        .map(|(r, d)| format!("{r:.2}:{d:.1}"))
        .collect::<Vec<_>>());
    println!(
        "\nCD tops out at ratio {:.2}; DCD reaches {:.2} — the flexibility the paper claims.",
        out.cd.iter().map(|p| p.0).fold(0.0, f64::max),
        out.dcd.iter().map(|p| p.0).fold(0.0, f64::max),
    );
    Ok(())
}
