"""Pure-jnp oracle for the network-step kernels.

These reference implementations are the correctness ground truth for the
Pallas kernels in ``dcd_kernel.py``: they follow the paper's equations as
directly as possible (dense N x N x L intermediates, no fusion) and are
compared entry-for-entry under pytest + hypothesis.

Conventions (shared with the rust engine — see rust/src/algorithms/):
  * ``W``  (N, L)  — local estimates w_{k,i-1}, row k = node k.
  * ``U``  (N, L)  — regressors u_{k,i}.
  * ``D``  (N,)    — desired responses d_k(i) (noise already included).
  * ``H``  (N, L)  — 0/1 estimate-send masks; row k is H_{k,i}'s diagonal.
  * ``Q``  (N, L)  — 0/1 gradient-send masks; row l is Q_{l,i}'s diagonal.
  * ``C``  (N, N)  — right-stochastic adapt weights; entry [l, k] = c_{lk}.
  * ``A``  (N, N)  — left-stochastic combine weights; entry [l, k] = a_{lk}.
  * ``mu`` (N,)    — per-node step sizes.
  * ``S``  (N, N)  — 0/1 RCD link-selection; [l, k] = 1 iff node k polls l.

All masks/weights are float arrays (0.0/1.0 for binaries) so that the same
buffers can be fed from rust without dtype juggling.
"""

import jax.numpy as jnp


def dcd_step_ref(W, U, D, H, Q, C, A, mu):
    """One synchronous DCD iteration (paper Alg. 1, eqs. (10)-(12)).

    Generalises several algorithms:
      * ``H = Q = 1`` and ``A = I``  -> diffusion LMS with A = I.
      * ``Q = 1``  (i.e. M_grad = L) -> compressed diffusion LMS (CD).
      * general H, Q                 -> doubly-compressed diffusion LMS.

    Returns ``(W_new, psi)`` with shapes (N, L), (N, L).
    """
    # Filled estimate node l uses on behalf of node k (Alg. 1 step 5):
    #   x[k, l, :] = H_k o w_k + (1 - H_k) o w_l
    x = H[:, None, :] * W[:, None, :] + (1.0 - H[:, None, :]) * W[None, :, :]
    # Residual at node l evaluated at the filled estimate: e[k, l].
    e = D[None, :] - jnp.einsum("lj,klj->kl", U, x)
    # Node k's own residual e_self[k] = d_k - u_k^T w_k.
    e_self = D - jnp.sum(U * W, axis=1)
    # Doubly-masked gradient g_{l,i} as seen by node k (eq. (12)):
    #   g[k, l, :] = Q_l o (u_l e[k,l]) + (1 - Q_l) o (u_k e_self[k])
    g = Q[None, :, :] * (U[None, :, :] * e[:, :, None]) + (
        1.0 - Q[None, :, :]
    ) * (U[:, None, :] * e_self[:, None, None])
    # Adapt (eq. (10)): psi_k = w_k + mu_k sum_l c_{lk} g[k, l].
    psi = W + mu[:, None] * jnp.einsum("lk,klj->kj", C, g)
    # Combine (eq. (11)): the l = k term uses psi_k itself.
    #   w_k = a_kk psi_k + sum_{l != k} a_lk (H_l o w_l + (1 - H_l) o psi_k)
    fill = H[:, None, :] * W[:, None, :] + (1.0 - H[:, None, :]) * psi[None, :, :]
    total = jnp.einsum("lk,lkj->kj", A, fill)
    akk = jnp.diagonal(A)
    # Swap the l = k term (a_kk (H_k o w_k + (1 - H_k) o psi_k)) for a_kk psi_k:
    W_new = total + akk[:, None] * H * (psi - W)
    return W_new, psi


def atc_step_ref(W, U, D, C, A, mu):
    """Textbook ATC diffusion LMS (eqs. (4)-(5)); the uncompressed baseline.

    Note this differs from ``dcd_step_ref`` with all-ones masks when A != I:
    ATC combines the *intermediate* estimates psi_l, while DCD reuses the
    w_{l,i-1} received during adaptation. With A = I the two coincide.
    """
    # e[k, l] = d_l - u_l^T w_k ; psi_k = w_k + mu_k sum_l c_lk u_l e[k, l]
    e = D[None, :] - W @ U.T  # (N, N): row k, col l
    psi = W + mu[:, None] * jnp.einsum("lk,kl,lj->kj", C, e, U)
    W_new = jnp.einsum("lk,lj->kj", A, psi)
    return W_new, psi


def rcd_step_ref(W, U, D, S, A, mu):
    """Reduced-communication diffusion LMS [29] (paper eq. (7)).

    Self-only adapt, then combine over the randomly selected neighbour
    subset S (entries [l, k], diagonal ignored):
      h_kk = 1 - sum_{l != k} S[l, k] a_lk
      w_k  = h_kk psi_k + sum_{l != k} S[l, k] a_lk psi_l
    """
    N, _ = W.shape
    psi = W + mu[:, None] * U * (D - jnp.sum(U * W, axis=1))[:, None]
    offdiag = 1.0 - jnp.eye(N, dtype=W.dtype)
    sel = S * A * offdiag  # [l, k] weight for neighbour l at node k
    hkk = 1.0 - jnp.sum(sel, axis=0)  # (N,)
    W_new = hkk[:, None] * psi + jnp.einsum("lk,lj->kj", sel, psi)
    return W_new, psi


def partial_step_ref(W, U, D, H, A, mu):
    """Partial-diffusion LMS [31]-[33] (paper eq. (8)).

    Self-only adapt; combine shares M entries of psi_l (mask row l), the
    receiver substitutes its own psi_k for the missing ones. The l = k term
    needs no correction because fill[k, k] = psi_k exactly.
    """
    psi = W + mu[:, None] * U * (D - jnp.sum(U * W, axis=1))[:, None]
    # fill[l, k, :] = H_l o psi_l + (1 - H_l) o psi_k
    fill = H[:, None, :] * psi[:, None, :] + (1.0 - H[:, None, :]) * psi[None, :, :]
    W_new = jnp.einsum("lk,lkj->kj", A, fill)
    return W_new, psi
