"""Layer-1 Pallas kernels: the per-iteration network-update hot spot.

The DCD iteration is, per node k, a fused pass over N x L panels:
mask-fill of the neighbour estimates, masked-residual computation, doubly
masked gradient assembly (eq. (12)), the adapt scaled-accumulation
(eq. (10)) and the combine (eq. (11)). The pure-jnp oracle in ``ref.py``
materialises an N x N x L tensor through ~10 separate XLA ops; this kernel
instead tiles the computation with grid=(N,) so each program touches only
(N, L) panels resident in VMEM, writing a single (1, L) output row per
program — one pass over the data instead of ten.

TPU mapping (DESIGN.md §Hardware-Adaptation): the panels are far below the
VMEM budget (80 x 40 f32 = 12.8 KiB each), the arithmetic is VPU
element-wise + row reductions (no MXU), so the kernel is memory-bound and
fusion is the whole game. ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls, and correctness is validated
against ``ref.py`` through that path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dcd_kernel(w_ref, u_ref, d_ref, h_ref, q_ref, c_ref, a_ref, mu_ref,
                wnew_ref, psi_ref):
    """One program instance = one node k (grid=(N,))."""
    k = pl.program_id(0)
    W = w_ref[...]
    U = u_ref[...]
    D = d_ref[...][:, 0]
    H = h_ref[...]
    Q = q_ref[...]

    wk = W[k, :]
    uk = U[k, :]
    hk = H[k, :]

    # Node k's own residual: e_self = d_k - u_k^T w_k.
    e_self = D[k] - jnp.sum(uk * wk)

    # Filled estimates every neighbour l evaluates for node k:
    #   x[l, :] = H_k o w_k + (1 - H_k) o w_l          (Alg. 1 step 5)
    x = hk[None, :] * wk[None, :] + (1.0 - hk[None, :]) * W
    # Residuals e[l] = d_l - u_l^T x[l].
    e = D - jnp.sum(U * x, axis=1)
    # Doubly-masked gradients (eq. (12)):
    #   g[l, :] = Q_l o (u_l e[l]) + (1 - Q_l) o (u_k e_self)
    g = Q * (U * e[:, None]) + (1.0 - Q) * (uk[None, :] * e_self)

    # Adapt (eq. (10)): psi_k = w_k + mu_k sum_l c_{lk} g[l].
    ck = c_ref[...][:, k]
    psi_k = wk + mu_ref[...][k, 0] * jnp.sum(ck[:, None] * g, axis=0)

    # Combine (eq. (11)). Sum the generic l-term for all l, then swap the
    # l = k contribution a_kk (H_k o w_k + (1 - H_k) o psi_k) for a_kk psi_k,
    # which collapses to adding a_kk H_k o (psi_k - w_k).
    ak = a_ref[...][:, k]
    fill = H * W + (1.0 - H) * psi_k[None, :]
    tot = jnp.sum(ak[:, None] * fill, axis=0)
    wnew = tot + a_ref[...][k, k] * hk * (psi_k - wk)

    wnew_ref[0, :] = wnew
    psi_ref[0, :] = psi_k


@functools.partial(jax.jit, static_argnames=())
def dcd_step_pallas(W, U, D, H, Q, C, A, mu):
    """Fused DCD network step. Same contract as ``ref.dcd_step_ref``."""
    N, L = W.shape
    full = lambda *shape: pl.BlockSpec(shape, lambda k: tuple(0 for _ in shape))
    row = pl.BlockSpec((1, L), lambda k: (k, 0))
    wnew, psi = pl.pallas_call(
        _dcd_kernel,
        grid=(N,),
        in_specs=[
            full(N, L),  # W
            full(N, L),  # U
            full(N, 1),  # D (column)
            full(N, L),  # H
            full(N, L),  # Q
            full(N, N),  # C
            full(N, N),  # A
            full(N, 1),  # mu (column)
        ],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((N, L), W.dtype),
            jax.ShapeDtypeStruct((N, L), W.dtype),
        ],
        interpret=True,
    )(W, U, D[:, None], H, Q, C, A, mu[:, None])
    return wnew, psi


def _partial_kernel(w_ref, u_ref, d_ref, h_ref, a_ref, mu_ref,
                    wnew_ref, psi_ref):
    """Partial-diffusion LMS step (eq. (8)); one program per node k."""
    k = pl.program_id(0)
    W = w_ref[...]
    U = u_ref[...]
    D = d_ref[...][:, 0]
    H = h_ref[...]
    mu = mu_ref[...][:, 0]

    # Self-only adapt for every node (each program recomputes the full psi
    # panel; N x L stays in VMEM and saves a second kernel launch).
    e = D - jnp.sum(U * W, axis=1)
    psi = W + mu[:, None] * U * e[:, None]

    psi_k = psi[k, :]
    ak = a_ref[...][:, k]
    # fill[l] = H_l o psi_l + (1 - H_l) o psi_k ; fill[k] = psi_k exactly.
    fill = H * psi + (1.0 - H) * psi_k[None, :]
    wnew = jnp.sum(ak[:, None] * fill, axis=0)

    wnew_ref[0, :] = wnew
    psi_ref[0, :] = psi_k


@functools.partial(jax.jit, static_argnames=())
def partial_step_pallas(W, U, D, H, A, mu):
    """Fused partial-diffusion step. Same contract as ``ref.partial_step_ref``."""
    N, L = W.shape
    full = lambda *shape: pl.BlockSpec(shape, lambda k: tuple(0 for _ in shape))
    row = pl.BlockSpec((1, L), lambda k: (k, 0))
    wnew, psi = pl.pallas_call(
        _partial_kernel,
        grid=(N,),
        in_specs=[full(N, L), full(N, L), full(N, 1), full(N, L),
                  full(N, N), full(N, 1)],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((N, L), W.dtype),
            jax.ShapeDtypeStruct((N, L), W.dtype),
        ],
        interpret=True,
    )(W, U, D[:, None], H, A, mu[:, None])
    return wnew, psi
