"""AOT lowering: JAX chunk models -> artifacts/*.hlo.txt + manifest.json.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the rust binary is self-contained after.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (name, N, L, T): shape configurations matching the paper's experiments.
# T is the scan-chunk length; the rust coordinator threads W_T across
# chunks, so total horizon is any multiple of T.
SHAPE_CONFIGS = [
    ("smoke", 4, 3, 8),      # tiny config for tests
    ("exp1", 10, 5, 500),    # Fig. 3 left  (N=10, L=5)
    ("exp2", 50, 50, 250),   # Fig. 3 center/right (N=50, L=50)
    ("exp3", 80, 40, 250),   # Fig. 4 (N=80, L=40)
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(algo: str, N: int, L: int, T: int) -> tuple[str, list]:
    specs = model.chunk_arg_specs(algo, N, L, T)
    fn = model.chunk_factory(algo, use_pallas=True)
    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    return to_hlo_text(lowered), specs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="",
                    help="comma-separated subset of config names (default all)")
    ap.add_argument("--algos", default=",".join(model.ALGORITHMS))
    args = ap.parse_args()

    wanted = set(filter(None, args.configs.split(",")))
    algos = [a for a in args.algos.split(",") if a]
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for cfg_name, N, L, T in SHAPE_CONFIGS:
        if wanted and cfg_name not in wanted:
            continue
        for algo in algos:
            name = f"{algo}_{cfg_name}"
            text, specs = lower_one(algo, N, L, T)
            path = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, path), "w") as f:
                f.write(text)
            entries.append({
                "name": name,
                "algo": algo,
                "config": cfg_name,
                "path": path,
                "n_nodes": N,
                "dim": L,
                "chunk_len": T,
                "inputs": [
                    {"name": nm, "shape": list(s.shape), "dtype": "f32"}
                    for nm, s in specs
                ],
                "outputs": [
                    {"name": "W_T", "shape": [N, L], "dtype": "f32"},
                    {"name": "MSD", "shape": [T, N], "dtype": "f32"},
                ],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"lowered {name}: {len(text)} chars")

    manifest = {"format": "hlo-text", "version": 1, "modules": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} modules to {args.out_dir}")


if __name__ == "__main__":
    main()
