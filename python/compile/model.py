"""Layer-2 JAX network models: T-step scan chunks over the L1 kernels.

Each ``make_*_chunk`` returns a function that advances the whole network T
iterations with ``lax.scan`` and emits the per-node squared-deviation
trajectory; ``aot.py`` lowers these once per (algorithm, N, L, T) to HLO
text that the rust runtime executes. Chunking amortises PJRT dispatch: the
rust coordinator feeds successive chunks, threading the final weights W_T
of one chunk into the next.

All inputs are runtime arguments (not baked constants) so the rust engine
and this engine can be driven with *identical* data, masks and combiners —
that equivalence is asserted by rust/tests/engines_agree.rs.

Chunk contracts (all f32):
  dcd:     (W0[N,L], U[T,N,L], D[T,N], H[T,N,L], Q[T,N,L],
            C[N,N], A[N,N], mu[N], wo[L])           -> (W_T[N,L], MSD[T,N])
  atc:     (W0, U, D, C, A, mu, wo)                 -> (W_T, MSD)
  rcd:     (W0, U, D, S[T,N,N], A, mu, wo)          -> (W_T, MSD)
  partial: (W0, U, D, H[T,N,L], A, mu, wo)          -> (W_T, MSD)

MSD[i, k] = || wo - w_{k,i} ||^2 after the update at chunk-local step i.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.dcd_kernel import dcd_step_pallas, partial_step_pallas

ALGORITHMS = ("dcd", "atc", "rcd", "partial")


def _sqdev(W, wo):
    d = wo[None, :] - W
    return jnp.sum(d * d, axis=1)


def make_dcd_chunk(use_pallas=True):
    step = dcd_step_pallas if use_pallas else ref.dcd_step_ref

    def chunk(W0, U, D, H, Q, C, A, mu, wo):
        def body(W, inp):
            u, d, h, q = inp
            W_new, _psi = step(W, u, d, h, q, C, A, mu)
            return W_new, _sqdev(W_new, wo)

        W_T, msd = jax.lax.scan(body, W0, (U, D, H, Q))
        return W_T, msd

    return chunk


def make_atc_chunk(use_pallas=True):
    # ATC is the uncompressed baseline; its step is two einsums and does
    # not warrant a dedicated kernel (the DCD kernel covers the fused case).
    del use_pallas

    def chunk(W0, U, D, C, A, mu, wo):
        def body(W, inp):
            u, d = inp
            W_new, _psi = ref.atc_step_ref(W, u, d, C, A, mu)
            return W_new, _sqdev(W_new, wo)

        W_T, msd = jax.lax.scan(body, W0, (U, D))
        return W_T, msd

    return chunk


def make_rcd_chunk(use_pallas=True):
    del use_pallas

    def chunk(W0, U, D, S, A, mu, wo):
        def body(W, inp):
            u, d, s = inp
            W_new, _psi = ref.rcd_step_ref(W, u, d, s, A, mu)
            return W_new, _sqdev(W_new, wo)

        W_T, msd = jax.lax.scan(body, W0, (U, D, S))
        return W_T, msd

    return chunk


def make_partial_chunk(use_pallas=True):
    step = partial_step_pallas if use_pallas else ref.partial_step_ref

    def chunk(W0, U, D, H, A, mu, wo):
        def body(W, inp):
            u, d, h = inp
            W_new, _psi = step(W, u, d, h, A, mu)
            return W_new, _sqdev(W_new, wo)

        W_T, msd = jax.lax.scan(body, W0, (U, D, H))
        return W_T, msd

    return chunk


def chunk_factory(algo, use_pallas=True):
    return {
        "dcd": make_dcd_chunk,
        "atc": make_atc_chunk,
        "rcd": make_rcd_chunk,
        "partial": make_partial_chunk,
    }[algo](use_pallas)


def chunk_arg_specs(algo, N, L, T):
    """ShapeDtypeStructs for lowering, in calling order, with names."""
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    common_head = [("W0", sd((N, L), f32)), ("U", sd((T, N, L), f32)),
                   ("D", sd((T, N), f32))]
    tail = [("A", sd((N, N), f32)), ("mu", sd((N,), f32)),
            ("wo", sd((L,), f32))]
    if algo == "dcd":
        mid = [("H", sd((T, N, L), f32)), ("Q", sd((T, N, L), f32)),
               ("C", sd((N, N), f32))]
    elif algo == "atc":
        mid = [("C", sd((N, N), f32))]
    elif algo == "rcd":
        mid = [("S", sd((T, N, N), f32))]
    elif algo == "partial":
        mid = [("H", sd((T, N, L), f32))]
    else:
        raise ValueError(f"unknown algo {algo!r}")
    return common_head + mid + tail
