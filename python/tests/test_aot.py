"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_lower_one_produces_hlo_text():
    text, specs = aot.lower_one("dcd", 4, 3, 8)
    assert text.startswith("HloModule"), text[:80]
    assert "while" in text  # the scan lowers to an HLO while loop
    assert [nm for nm, _ in specs][0] == "W0"


@pytest.mark.parametrize("algo", model.ALGORITHMS)
def test_lowering_all_algos_smoke_shape(algo):
    text, _ = aot.lower_one(algo, 4, 3, 8)
    assert text.startswith("HloModule")
    # 9 inputs for dcd, fewer for the rest — all must appear as parameters.
    n_params = text.count("parameter(")
    assert n_params >= 6


def test_cli_writes_manifest(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--configs", "smoke", "--algos", "dcd,atc"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {m["name"] for m in manifest["modules"]}
    assert names == {"dcd_smoke", "atc_smoke"}
    for m in manifest["modules"]:
        body = (tmp_path / m["path"]).read_text()
        assert body.startswith("HloModule")
        import hashlib

        assert hashlib.sha256(body.encode()).hexdigest() == m["sha256"]
        # Input element counts are consistent with N, L, T.
        N, L, T = m["n_nodes"], m["dim"], m["chunk_len"]
        by_name = {t["name"]: t["shape"] for t in m["inputs"]}
        assert by_name["W0"] == [N, L]
        assert by_name["U"] == [T, N, L]
        assert by_name["wo"] == [L]
