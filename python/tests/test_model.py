"""L2 correctness: scan chunks vs repeated single steps, MSD semantics."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref
from .test_kernel import random_masks, random_problem


def _chunk_inputs(seed, N, L, T, M, Mg):
    rng = np.random.default_rng(seed)
    W0 = np.zeros((N, L), np.float32)
    wo = rng.normal(size=L).astype(np.float32)
    U = rng.normal(size=(T, N, L)).astype(np.float32)
    V = (0.03 * rng.normal(size=(T, N))).astype(np.float32)
    D = np.einsum("tnl,l->tn", U, wo).astype(np.float32) + V
    H = np.stack([random_masks(rng, N, L, M) for _ in range(T)])
    Q = np.stack([random_masks(rng, N, L, Mg) for _ in range(T)])
    Craw = rng.random((N, N)).astype(np.float32) + 0.1
    C = Craw / Craw.sum(axis=1, keepdims=True)
    Araw = rng.random((N, N)).astype(np.float32) + 0.1
    A = Araw / Araw.sum(axis=0, keepdims=True)
    mu = np.full(N, 0.05, np.float32)
    return W0, U, D, H, Q, C, A, mu, wo


@pytest.mark.parametrize("use_pallas", [False, True])
def test_dcd_chunk_equals_unrolled_steps(use_pallas):
    N, L, T = 5, 4, 7
    W0, U, D, H, Q, C, A, mu, wo = _chunk_inputs(0, N, L, T, 2, 1)
    chunk = model.make_dcd_chunk(use_pallas=use_pallas)
    W_T, msd = chunk(*map(jnp.asarray, (W0, U, D, H, Q, C, A, mu, wo)))
    # Unrolled reference.
    W = jnp.asarray(W0)
    for t in range(T):
        W, _ = ref.dcd_step_ref(W, U[t], D[t], H[t], Q[t], C, A, mu)
        expect = np.sum((wo[None, :] - np.asarray(W)) ** 2, axis=1)
        np.testing.assert_allclose(msd[t], expect, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(W_T, W, rtol=1e-4, atol=1e-6)


def test_chunks_compose():
    """Two T-chunks threaded by W_T must equal one 2T-chunk."""
    N, L, T = 4, 3, 6
    W0, U, D, H, Q, C, A, mu, wo = _chunk_inputs(1, N, L, 2 * T, 2, 1)
    chunk = model.make_dcd_chunk(use_pallas=True)
    as_j = jnp.asarray
    W_full, msd_full = chunk(as_j(W0), as_j(U), as_j(D), as_j(H), as_j(Q),
                             as_j(C), as_j(A), as_j(mu), as_j(wo))
    W_a, msd_a = chunk(as_j(W0), as_j(U[:T]), as_j(D[:T]), as_j(H[:T]),
                       as_j(Q[:T]), as_j(C), as_j(A), as_j(mu), as_j(wo))
    W_b, msd_b = chunk(W_a, as_j(U[T:]), as_j(D[T:]), as_j(H[T:]),
                       as_j(Q[T:]), as_j(C), as_j(A), as_j(mu), as_j(wo))
    np.testing.assert_allclose(W_b, W_full, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.concatenate([msd_a, msd_b]), msd_full, rtol=1e-4, atol=1e-6
    )


@pytest.mark.parametrize("algo", model.ALGORITHMS)
def test_chunks_converge_noiseless(algo):
    """Every algorithm's chunk must drive MSD down on noiseless data."""
    N, L, T = 6, 4, 60
    rng = np.random.default_rng(5)
    wo = rng.normal(size=L).astype(np.float32)
    U = rng.normal(size=(T, N, L)).astype(np.float32)
    D = np.einsum("tnl,l->tn", U, wo).astype(np.float32)
    W0 = np.zeros((N, L), np.float32)
    eye = np.eye(N, dtype=np.float32)
    ring = 0.5 * eye + 0.25 * np.roll(eye, 1, 0) + 0.25 * np.roll(eye, -1, 0)
    mu = np.full(N, 0.08, np.float32)
    chunk = model.chunk_factory(algo, use_pallas=True)
    as_j = jnp.asarray
    if algo == "dcd":
        H = np.stack([random_masks(rng, N, L, 2) for _ in range(T)])
        Q = np.stack([random_masks(rng, N, L, 2) for _ in range(T)])
        _, msd = chunk(as_j(W0), as_j(U), as_j(D), as_j(H), as_j(Q),
                       as_j(ring), as_j(ring), as_j(mu), as_j(wo))
    elif algo == "atc":
        _, msd = chunk(as_j(W0), as_j(U), as_j(D), as_j(ring), as_j(ring),
                       as_j(mu), as_j(wo))
    elif algo == "rcd":
        S = (rng.random((T, N, N)) < 0.5).astype(np.float32)
        _, msd = chunk(as_j(W0), as_j(U), as_j(D), as_j(S), as_j(ring),
                       as_j(mu), as_j(wo))
    else:  # partial
        H = np.stack([random_masks(rng, N, L, 2) for _ in range(T)])
        _, msd = chunk(as_j(W0), as_j(U), as_j(D), as_j(H), as_j(ring),
                       as_j(mu), as_j(wo))
    start = float(np.mean(msd[0]))
    end = float(np.mean(msd[-1]))
    assert end < 0.2 * start, f"{algo}: msd {start} -> {end}"


def test_arg_specs_match_chunk_signature():
    for algo in model.ALGORITHMS:
        N, L, T = 4, 3, 5
        specs = model.chunk_arg_specs(algo, N, L, T)
        names = [nm for nm, _ in specs]
        assert names[0] == "W0" and names[-1] == "wo"
        # Every spec shape must be accepted by the chunk without error.
        chunk = model.chunk_factory(algo, use_pallas=False)
        args = [jnp.zeros(s.shape, s.dtype) for _, s in specs]
        W_T, msd = chunk(*args)
        assert W_T.shape == (N, L)
        assert msd.shape == (T, N)
