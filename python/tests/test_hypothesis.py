"""Hypothesis sweeps of the Pallas kernels' shape/parameter space.

Each property draws network size, dimension, compression levels, dtypes
and data, and asserts the kernel ≡ oracle identity plus structural
invariants that must hold for *any* valid configuration.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dcd_kernel import dcd_step_pallas, partial_step_pallas


@st.composite
def dcd_problem(draw):
    N = draw(st.integers(min_value=2, max_value=8))
    L = draw(st.integers(min_value=1, max_value=8))
    M = draw(st.integers(min_value=0, max_value=L))
    Mg = draw(st.integers(min_value=0, max_value=L))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(N, L)).astype(np.float32)
    U = rng.normal(size=(N, L)).astype(np.float32)
    D = rng.normal(size=(N,)).astype(np.float32)

    def masks(m):
        out = np.zeros((N, L), np.float32)
        for k in range(N):
            out[k, rng.choice(L, size=m, replace=False)] = 1.0
        return out

    H, Q = masks(M), masks(Mg)
    Craw = rng.random((N, N)).astype(np.float32) + 0.05
    C = Craw / Craw.sum(axis=1, keepdims=True)
    Araw = rng.random((N, N)).astype(np.float32) + 0.05
    A = Araw / Araw.sum(axis=0, keepdims=True)
    mu = (0.2 * rng.random(N)).astype(np.float32)
    return W, U, D, H, Q, C, A, mu


@settings(max_examples=60, deadline=None)
@given(dcd_problem())
def test_dcd_kernel_equals_oracle(problem):
    W, U, D, H, Q, C, A, mu = problem
    w_ref, p_ref = ref.dcd_step_ref(*map(jnp.asarray, (W, U, D, H, Q, C, A, mu)))
    w_ker, p_ker = dcd_step_pallas(*map(jnp.asarray, (W, U, D, H, Q, C, A, mu)))
    np.testing.assert_allclose(w_ker, w_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(p_ker, p_ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(dcd_problem())
def test_partial_kernel_equals_oracle(problem):
    W, U, D, H, _Q, _C, A, mu = problem
    w_ref, p_ref = ref.partial_step_ref(*map(jnp.asarray, (W, U, D, H, A, mu)))
    w_ker, p_ker = partial_step_pallas(*map(jnp.asarray, (W, U, D, H, A, mu)))
    np.testing.assert_allclose(w_ker, w_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(p_ker, p_ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(dcd_problem())
def test_exact_consensus_is_fixed_point(problem):
    """If all nodes hold wo and data is noiseless, nothing moves —
    for any masks and any combiners."""
    W, U, _D, H, Q, C, A, mu = problem
    N, L = W.shape
    wo = W[0]
    Wc = np.tile(wo, (N, 1))
    D0 = np.sum(U * Wc, axis=1)
    w_new, psi = dcd_step_pallas(*map(jnp.asarray, (Wc, U, D0, H, Q, C, A, mu)))
    np.testing.assert_allclose(psi, Wc, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(w_new, Wc, rtol=1e-4, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(dcd_problem())
def test_zero_step_only_combines(problem):
    """mu = 0 must freeze the adapt step: psi == W for any configuration."""
    W, U, D, H, Q, C, A, _mu = problem
    mu0 = np.zeros(W.shape[0], np.float32)
    w_new, psi = dcd_step_pallas(*map(jnp.asarray, (W, U, D, H, Q, C, A, mu0)))
    np.testing.assert_allclose(psi, W, rtol=1e-6, atol=1e-6)
    # And the combine is then a convex recombination of rows of W:
    # each output entry lies within [min, max] of the corresponding column.
    w_new = np.asarray(w_new)
    lo = W.min(axis=0) - 1e-5
    hi = W.max(axis=0) + 1e-5
    assert (w_new >= lo[None, :]).all() and (w_new <= hi[None, :]).all()


@settings(max_examples=30, deadline=None)
@given(dcd_problem(), st.integers(min_value=0, max_value=10**6))
def test_float64_agrees_with_float32(problem, _salt):
    """The kernel math is dtype-generic: f64 run ≈ f32 run (loose tol)."""
    W, U, D, H, Q, C, A, mu = problem
    w32, _ = dcd_step_pallas(*map(jnp.asarray, (W, U, D, H, Q, C, A, mu)))
    args64 = [jnp.asarray(x.astype(np.float64)) for x in (W, U, D, H, Q, C, A, mu)]
    w64, _ = ref.dcd_step_ref(*args64)
    np.testing.assert_allclose(np.asarray(w32, np.float64), w64, rtol=1e-3, atol=1e-4)
