"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compiled compute path: the
AOT artifacts embed the Pallas kernels, and everything the rust runtime
executes flows through them.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.dcd_kernel import dcd_step_pallas, partial_step_pallas


def random_masks(rng, n, dim, m):
    """n x dim binary mask matrix with exactly m ones per row."""
    out = np.zeros((n, dim), np.float32)
    for k in range(n):
        out[k, rng.choice(dim, size=m, replace=False)] = 1.0
    return out


def random_problem(seed, N, L, M, Mg):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(N, L)).astype(np.float32)
    U = rng.normal(size=(N, L)).astype(np.float32)
    D = rng.normal(size=(N,)).astype(np.float32)
    H = random_masks(rng, N, L, M)
    Q = random_masks(rng, N, L, Mg)
    Craw = rng.random((N, N)).astype(np.float32) + 0.1
    C = Craw / Craw.sum(axis=1, keepdims=True)          # right-stochastic
    Araw = rng.random((N, N)).astype(np.float32) + 0.1
    A = Araw / Araw.sum(axis=0, keepdims=True)          # left-stochastic
    mu = (0.05 + 0.1 * rng.random(N)).astype(np.float32)
    return W, U, D, H, Q, C, A, mu


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("N,L,M,Mg", [(4, 3, 2, 1), (6, 5, 3, 1), (10, 5, 3, 1), (8, 8, 5, 4)])
def test_dcd_kernel_matches_ref(seed, N, L, M, Mg):
    W, U, D, H, Q, C, A, mu = random_problem(seed, N, L, M, Mg)
    w_ref, p_ref = ref.dcd_step_ref(W, U, D, H, Q, C, A, mu)
    w_ker, p_ker = dcd_step_pallas(W, U, D, H, Q, C, A, mu)
    np.testing.assert_allclose(w_ker, w_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(p_ker, p_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("N,L,M", [(4, 3, 2), (10, 5, 3), (8, 8, 5)])
def test_partial_kernel_matches_ref(seed, N, L, M):
    W, U, D, H, _Q, _C, A, mu = random_problem(seed, N, L, M, 1)
    w_ref, p_ref = ref.partial_step_ref(W, U, D, H, A, mu)
    w_ker, p_ker = partial_step_pallas(W, U, D, H, A, mu)
    np.testing.assert_allclose(w_ker, w_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(p_ker, p_ref, rtol=1e-5, atol=1e-5)


def test_dcd_full_masks_equals_atc_with_identity_A():
    """With M = M_grad = L and A = I, DCD *is* diffusion LMS (paper §III)."""
    N, L = 6, 4
    W, U, D, _H, _Q, C, _A, mu = random_problem(3, N, L, 2, 2)
    ones = np.ones((N, L), np.float32)
    eye = np.eye(N, dtype=np.float32)
    w_dcd, p_dcd = ref.dcd_step_ref(W, U, D, ones, ones, C, eye, mu)
    w_atc, p_atc = ref.atc_step_ref(W, U, D, C, eye, mu)
    np.testing.assert_allclose(w_dcd, w_atc, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(p_dcd, p_atc, rtol=1e-5, atol=1e-6)


def test_dcd_q_full_is_cd():
    """M_grad = L (Q = 1) is the compressed-diffusion special case: the
    gradient part must then equal the ATC gradient evaluated at the filled
    estimates, and psi must not depend on Q at all."""
    N, L, M = 5, 4, 2
    W, U, D, H, _Q, C, _A, mu = random_problem(7, N, L, M, 2)
    ones = np.ones((N, L), np.float32)
    eye = np.eye(N, dtype=np.float32)
    w1, p1 = ref.dcd_step_ref(W, U, D, H, ones, C, eye, mu)
    # Q full => g[k,l] = u_l e[k,l]; independent reimplementation:
    x = H[:, None, :] * W[:, None, :] + (1 - H[:, None, :]) * W[None, :, :]
    e = D[None, :] - np.einsum("lj,klj->kl", U, x)
    g = U[None, :, :] * e[:, :, None]
    psi = W + mu[:, None] * np.einsum("lk,klj->kj", C, g)
    np.testing.assert_allclose(p1, psi, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(w1, psi, rtol=1e-4, atol=1e-5)  # A = I


def test_combine_is_convex_mixture():
    """Combine output lies in the affine hull of {psi_k} U {w_l}: with
    constant weight vectors everywhere, combine returns that constant."""
    N, L = 5, 3
    _, U, D, H, Q, C, A, mu = random_problem(11, N, L, 2, 1)
    const = np.full((N, L), 2.5, np.float32)
    # At W = const with D = U @ const, every residual is zero => psi = W,
    # and the combine of identical vectors is the same vector (A columns
    # sum to 1).
    D0 = np.sum(U * const, axis=1)
    w_new, psi = ref.dcd_step_ref(const, U, D0, H, Q, C, A, mu)
    np.testing.assert_allclose(psi, const, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(w_new, const, rtol=1e-5, atol=1e-5)


def test_rcd_no_links_is_pure_lms():
    """With no selected neighbours, RCD must collapse to stand-alone LMS."""
    N, L = 5, 3
    W, U, D, _H, _Q, _C, A, mu = random_problem(13, N, L, 2, 1)
    S = np.zeros((N, N), np.float32)
    w_new, psi = ref.rcd_step_ref(W, U, D, S, A, mu)
    lms = W + mu[:, None] * U * (D - np.sum(U * W, axis=1))[:, None]
    np.testing.assert_allclose(psi, lms, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(w_new, lms, rtol=1e-5, atol=1e-6)


def test_partial_full_mask_is_plain_diffusion_combine():
    """H = 1 makes partial diffusion an ordinary combine of the psi_l."""
    N, L = 5, 3
    W, U, D, _H, _Q, _C, A, mu = random_problem(17, N, L, 2, 1)
    ones = np.ones((N, L), np.float32)
    w_new, psi = ref.partial_step_ref(W, U, D, ones, A, mu)
    expect = np.einsum("lk,lj->kj", A, np.asarray(psi))
    np.testing.assert_allclose(w_new, expect, rtol=1e-5, atol=1e-6)


def test_gradient_descends_cost():
    """One DCD step from w = 0 with small mu must reduce the instantaneous
    squared error on average (sanity of sign conventions)."""
    rng = np.random.default_rng(23)
    N, L = 8, 6
    wo = rng.normal(size=L).astype(np.float32)
    U = rng.normal(size=(N, L)).astype(np.float32)
    D = (U @ wo).astype(np.float32)
    W = np.zeros((N, L), np.float32)
    H = random_masks(rng, N, L, 4)
    Q = random_masks(rng, N, L, 3)
    C = np.eye(N, dtype=np.float32)
    A = np.eye(N, dtype=np.float32)
    mu = np.full(N, 0.05, np.float32)
    w_new, _ = ref.dcd_step_ref(W, U, D, H, Q, C, A, mu)
    before = np.linalg.norm(W - wo[None, :])
    after = np.linalg.norm(np.asarray(w_new) - wo[None, :])
    assert after < before
